"""Scheduler property tests (ISSUE 1 satellite): corrupted orders are
rejected, segment boundaries exactly tile the stream, and segment count
equals δ_after + 1.

Written seed-parametrized (no hypothesis dependency) so they always run
under the tier-1 command; the hypothesis-based DAG sweep lives in
test_phase34.py and activates when the optional dep is installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.capture import trace_to_graph
from repro.core.lowering import lower_to_rgir
from repro.core.passes import run_forge_passes
from repro.core.scheduler import (
    Segment,
    compute_segments,
    schedule,
    verify_topological,
)


def random_dag_program(seed: int, n_ops: int = 10):
    """Lower a random primitive DAG mixing host and accel ops."""
    rng = np.random.default_rng(seed)

    def f(x):
        vals = [x]
        for _ in range(n_ops):
            a = vals[int(rng.integers(0, len(vals)))]
            b = vals[int(rng.integers(0, len(vals)))]
            op = int(rng.integers(0, 3))
            if op == 0:
                vals.append(a + b)  # host
            elif op == 1:
                vals.append(a * 0.5 + jnp.tanh(b))  # host
            else:
                vals.append(a @ b)  # accel (dot_general)
        return vals[-1]

    return lower_to_rgir(trace_to_graph(f, np.ones((4, 4), np.float32)).graph)


SEEDS = list(range(25))


def block_program(block_fn, block_args):
    g = trace_to_graph(block_fn, *block_args).graph
    run_forge_passes(g)
    return lower_to_rgir(g)


class TestVerifyTopologicalRejects:
    def test_rejects_swapped_dependency(self, block_fn, block_args):
        """Deliberately corrupt the order: swap a producer after its reader."""
        prog = block_program(block_fn, block_args)
        res = schedule(prog)
        verify_topological(prog, res.order)  # sanity: valid as produced
        pos = {old: new for new, old in enumerate(res.order)}
        # find a (producer, consumer) pair and swap their slots
        writer = {}
        for i, op in enumerate(prog.ops):
            for r in op.output_regs:
                writer[r] = i
        for i, op in enumerate(prog.ops):
            for r in op.input_regs:
                w = writer.get(r)
                if w is not None and w != i:
                    bad = list(res.order)
                    bad[pos[w]], bad[pos[i]] = bad[pos[i]], bad[pos[w]]
                    with pytest.raises(AssertionError, match="violates"):
                        verify_topological(prog, bad)
                    return
        pytest.fail("block program has no data dependency?!")

    @pytest.mark.parametrize("seed", SEEDS[:10])
    def test_rejects_corrupted_random_dags(self, seed):
        prog = random_dag_program(seed)
        res = schedule(prog)
        rng = np.random.default_rng(seed)
        rejected = False
        for _ in range(20):
            bad = list(res.order)
            i, j = rng.integers(0, len(bad), 2)
            if i == j:
                continue
            bad[i], bad[j] = bad[j], bad[i]
            try:
                verify_topological(prog, bad)
            except AssertionError:
                rejected = True
        # on a 10-op chain-ish DAG at least one random swap must violate
        assert rejected

    def test_accepts_valid_order(self):
        prog = random_dag_program(0)
        verify_topological(prog, list(range(len(prog.ops))))


class TestSegmentTiling:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_segments_exactly_tile_stream(self, seed):
        prog = random_dag_program(seed)
        res = schedule(prog)
        n = len(prog.ops)
        assert res.segments[0].start == 0
        assert res.segments[-1].stop == n
        for a, b in zip(res.segments, res.segments[1:]):
            assert a.stop == b.start  # contiguous, no gap, no overlap
            assert a.device != b.device  # maximality
        assert sum(len(s) for s in res.segments) == n
        # every instruction inside a segment is on the segment's device
        scheduled = prog.renumber(res.order)
        for seg in res.segments:
            for i in range(seg.start, seg.stop):
                assert scheduled.ops[i].device == seg.device

    @pytest.mark.parametrize("seed", SEEDS)
    def test_segment_count_is_delta_plus_one(self, seed):
        prog = random_dag_program(seed)
        res = schedule(prog)
        assert res.n_segments == res.delta_after + 1

    def test_segment_count_on_block(self, block_fn, block_args):
        prog = block_program(block_fn, block_args)
        res = schedule(prog)
        assert res.n_segments == res.delta_after + 1

    def test_compute_segments_unit(self):
        segs = compute_segments(["a", "a", "h", "h", "h", "a"])
        assert segs == [
            Segment(0, 2, "a"),
            Segment(2, 5, "h"),
            Segment(5, 6, "a"),
        ]
        assert compute_segments([]) == []
        assert compute_segments(["h"]) == [Segment(0, 1, "h")]
