"""Optional-hypothesis shim for the test suite.

``hypothesis`` is an *optional* test dependency (declared under the
``test`` extra in pyproject.toml).  When it is absent the property tests
must skip cleanly instead of aborting collection with ModuleNotFoundError
— which previously took the whole tier-1 suite down.  Import ``given``,
``settings`` and ``st`` from here instead of from ``hypothesis``.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # deliberately NOT functools.wraps: the strategy parameters
            # must not leak into the signature pytest resolves fixtures from
            def wrapper(self=None):  # noqa: ARG001
                pytest.skip("hypothesis not installed (pip install .[test])")

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):  # noqa: ARG001
                return None

            return strategy

    st = _StrategyStub()
