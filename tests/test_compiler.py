"""ForgeCompiler facade, metrics (FGR/CEI/fidelity), autotuner tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AutotuningCompiler,
    ForgeCompiler,
    PipelineConfig,
    forge_compile,
)
from repro.core.metrics import (
    check_compilation_fidelity,
    compilation_efficiency_index,
    fidelity,
    fusion_gain_ratio,
)


class TestFacade:
    def test_end_to_end(self, block_fn, block_args):
        mod = forge_compile(block_fn, *block_args)
        r = mod.result
        assert r.nodes_after < r.nodes_before
        assert r.attention_fused >= 1
        assert r.fused_ops >= 3
        assert r.total_ms > 0
        out = mod(*block_args)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(block_fn(*block_args), np.float32),
            rtol=1e-4, atol=1e-4,
        )

    def test_pass_table(self, block_fn, block_args):
        mod = forge_compile(block_fn, *block_args)
        table = {row["pass"]: row for row in mod.result.pass_table()}
        # all six paper passes + device-constant present
        for name in ("dce", "cse", "constant_folding", "device_constant",
                     "attention_fusion", "operator_fusion",
                     "layout_optimization"):
            assert name in table, name
            assert table[name]["time_ms"] >= 0

    def test_jit_mode(self, block_fn, block_args):
        mod = forge_compile(block_fn, *block_args)
        out = mod.jit()(*block_args)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(block_fn(*block_args), np.float32),
            rtol=1e-3, atol=1e-3,
        )

    def test_tied_weight_module(self, rng):
        w = rng.standard_normal((8, 8)).astype(np.float32) * 0.3

        def lm(params, x):
            h = jnp.tanh(x @ params["emb"])
            return h @ params["head"].T

        params = {"emb": w, "head": w}
        x = rng.standard_normal((2, 8)).astype(np.float32)
        mod = forge_compile(lm, params, x)
        assert mod.result.tied_weights == 1
        np.testing.assert_allclose(
            np.asarray(mod(params, x)), np.asarray(lm(params, x)),
            rtol=1e-5, atol=1e-5,
        )

    def test_summary_renders(self, block_fn, block_args):
        mod = forge_compile(block_fn, *block_args)
        s = mod.result.summary()
        assert "nodes:" in s and "rho_buf" in s


class TestMetrics:
    def test_fgr_above_one(self, block_fn, block_args):
        r = fusion_gain_ratio(block_fn, *block_args)
        assert r["fgr"] > 1.0
        assert r["score_alpha1"] < r["score_alpha0"]

    def test_cei(self):
        # 2x speedup for 0.5 s compile -> CEI 4.0
        assert compilation_efficiency_index(10.0, 5.0, 500.0) == pytest.approx(4.0)

    def test_fidelity_protocol(self, block_fn, block_args):
        rep = check_compilation_fidelity(block_fn, *block_args)
        # unit-scale weights -> tight numerical agreement
        assert rep.max_abs_diff < 1e-3
        assert rep.kl_divergence < 1e-6

    def test_fidelity_identical(self):
        a = {"logits": jnp.ones((2, 8))}
        rep = fidelity(a, a)
        assert rep.max_abs_diff == 0.0 and rep.kl_divergence == 0.0


class TestAutotuner:
    def test_grid_size(self, block_fn, block_args):
        tr = AutotuningCompiler().tune(block_fn, *block_args)
        assert len(tr.candidates) >= 45
        assert tr.best.score <= min(c.score for c in tr.candidates)

    def test_autotuned_compile_runs(self, block_fn, block_args):
        mod = AutotuningCompiler().compile(block_fn, *block_args)
        out = mod(*block_args)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(block_fn(*block_args), np.float32),
            rtol=1e-4, atol=1e-4,
        )

    def test_aggressive_fusion_wins(self, block_fn, block_args):
        """Paper Table 17: cost improves monotonically with α."""
        from repro.core.capture import trace_to_graph
        from repro.core.cost_model import score_graph
        from repro.core.passes import run_forge_passes

        scores = []
        for alpha in (0.0, 0.5, 1.0):
            g = trace_to_graph(block_fn, *block_args).graph
            run_forge_passes(g, cfg=PipelineConfig(alpha=alpha))
            scores.append(score_graph(g).score)
        assert scores[0] >= scores[1] >= scores[2]
        assert scores[2] < scores[0]
