"""Per-architecture smoke tests (reduced configs): one forward + one
decode step on CPU, shape and NaN assertions, Forge-vs-raw fidelity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, input_specs, shape_applicable
from repro.models import get_model, losses

B, S = 2, 16
KEY = jax.random.PRNGKey(0)


def _forward(model, params, cfg, tokens, key):
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, S, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        return model.apply(params, frames, tokens, cfg), frames
    if cfg.family == "vlm":
        patches = jax.random.normal(key, (B, 4, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
        return model.module.apply(params, tokens, cfg,
                                  patch_embeds=patches), patches
    return model.apply(params, tokens, cfg), None


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param, smoke=True)
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return cfg, model, params, tokens


class TestSmokeForward:
    def test_forward_shapes_no_nan(self, arch_setup):
        cfg, model, params, tokens = arch_setup
        logits, _ = _forward(model, params, cfg, tokens, KEY)
        lo = np.asarray(logits, np.float32)
        assert lo.shape[0] == B and lo.shape[-1] == cfg.vocab
        assert np.all(np.isfinite(lo)), f"{cfg.name}: non-finite logits"

    def test_decode_step(self, arch_setup):
        cfg, model, params, tokens = arch_setup
        tok = tokens[:, :1]
        pos = jnp.asarray(0, jnp.int32)
        if cfg.family == "encdec":
            frames = jax.random.normal(KEY, (B, S, cfg.d_model),
                                       jnp.dtype(cfg.dtype))
            cache = model.init_cache(params, frames, cfg, max_len=32)
        elif cfg.family in ("hybrid", "ssm"):
            cache = model.init_cache(cfg, B, 32)
        else:
            cache = model.init_cache(cfg, B, 32)
        logits, cache2 = model.decode_step(params, cache, tok, pos, cfg)
        lo = np.asarray(logits, np.float32)
        assert lo.shape == (B, 1, cfg.vocab)
        assert np.all(np.isfinite(lo))
        # cache must have been written (not all zeros anymore) for attn archs
        if cfg.family in ("dense", "moe", "vlm"):
            assert float(jnp.sum(jnp.abs(cache2["k"]))) > 0

    def test_train_grad_finite(self, arch_setup):
        cfg, model, params, tokens = arch_setup
        if cfg.family in ("encdec", "vlm"):
            pytest.skip("grad smoke covered via dense/moe/ssm paths")

        def loss_fn(p):
            logits = model.apply(p, tokens, cfg)
            return losses.cross_entropy(logits[:, :-1], tokens[:, 1:])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss))
        leaves = jax.tree_util.tree_leaves(grads)
        assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
                   for g in leaves)


class TestForgeFidelity:
    def test_fuse_matches_raw(self, arch_setup):
        """cfg.fuse='forge' vs 'none' must agree (scan-family archs)."""
        cfg, model, params, tokens = arch_setup
        if cfg.family not in ("dense", "moe", "vlm"):
            pytest.skip("forge block integration is scan-family only")
        logits_f, _ = _forward(model, params, cfg, tokens, KEY)
        cfg_n = cfg.with_(fuse="none")
        logits_n, _ = _forward(model, params, cfg_n, tokens, KEY)
        lf = np.asarray(logits_f, np.float32)
        ln = np.asarray(logits_n, np.float32)
        # fused kernels reorder float accumulation, so isolated logits can
        # exceed a pointwise 2e-2 tolerance: pin the bulk tight, bound the
        # outlier tail.  MoE gets a looser tail bound — top-k routing is
        # discontinuous and a borderline token can flip experts outright.
        min_within, max_tail = (
            (0.995, 0.15) if cfg.family == "moe" else (0.999, 0.1)
        )
        within = np.abs(lf - ln) <= 2e-2 + 2e-2 * np.abs(ln)
        assert within.mean() >= min_within, (
            f"{(~within).sum()} / {within.size} logits off"
        )
        assert np.max(np.abs(lf - ln)) < max_tail


class TestConfigs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_full_config_loads(self, arch):
        cfg = get_config(arch)
        assert cfg.param_count() > 1e8
        assert cfg.n_layers >= 24 or cfg.family in ("encdec",)

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_input_specs_all_shapes(self, arch):
        cfg = get_config(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            runs, reason = shape_applicable(cfg, shape)
            if not runs:
                assert reason
                continue
            specs = input_specs(cfg, shape)
            leaves = jax.tree_util.tree_leaves(specs)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)

    def test_long_500k_applicability(self):
        assert shape_applicable(get_config("xlstm-350m"), "long_500k")[0]
        assert shape_applicable(get_config("recurrentgemma-2b"), "long_500k")[0]
        assert not shape_applicable(get_config("deepseek-7b"), "long_500k")[0]

    def test_registry_complete(self):
        assert len(ARCH_IDS) == 10

    def test_moe_active_params(self):
        cfg = get_config("kimi-k2-1t-a32b")
        assert 0.9e12 < cfg.param_count() < 1.2e12
        assert 25e9 < cfg.active_param_count() < 40e9
