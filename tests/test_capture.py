"""Phase-1 capture tests: inlining, tied weights, forge markers, replay."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.capture import graph_to_fn, trace_to_graph
from repro.kernels.ops import forge_op


class TestInlining:
    def test_softmax_is_flat(self):
        def f(x):
            return jax.nn.softmax(x, axis=-1)

        g = trace_to_graph(f, np.ones((4, 8), np.float32)).graph
        ops = {n.op for n in g.nodes.values()}
        # softmax inlined to primitive chain, no opaque pjit equation
        assert "exp" in ops and "reduce_max" in ops and "div" in ops
        assert not any(o in ("pjit", "jit", "closed_call") for o in ops)

    def test_custom_jvp_inlined(self):
        def f(x):
            return jax.nn.relu(x) + jax.nn.gelu(x)

        g = trace_to_graph(f, np.ones((4,), np.float32)).graph
        assert not any("custom" in n.op for n in g.nodes.values())

    def test_scan_stays_opaque(self):
        def f(x):
            def body(c, t):
                return c + t, c

            return jax.lax.scan(body, x, jnp.arange(3.0))

        g = trace_to_graph(f, np.float32(1.0)).graph
        assert any(n.op == "scan" for n in g.nodes.values())

    def test_forge_marker_stays_opaque(self):
        @forge_op("mything")
        def mything(x):
            return jnp.tanh(x) * 2.0

        def f(x):
            return mything(x) + 1.0

        g = trace_to_graph(f, np.ones((4,), np.float32)).graph
        assert any(n.op == "forge.mything" for n in g.nodes.values())


class TestTiedWeights:
    def test_tied_leaves_merge(self):
        w = np.ones((4, 4), np.float32)

        def f(params, x):
            return (x @ params["emb"]) @ params["head"]

        params = {"emb": w, "head": w}  # same object: tied
        res = trace_to_graph(f, params, np.ones((2, 4), np.float32))
        assert len(res.tied_map) == 1
        assert len(res.graph.invars) == res.n_inputs_raw - 1

    def test_untied_leaves_not_merged(self):
        def f(params, x):
            return (x @ params["emb"]) @ params["head"]

        params = {
            "emb": np.ones((4, 4), np.float32),
            "head": np.ones((4, 4), np.float32),  # equal values, diff objects
        }
        res = trace_to_graph(f, params, np.ones((2, 4), np.float32))
        assert res.tied_map == {}

    def test_tied_replay_correct(self):
        w = np.random.default_rng(0).standard_normal((4, 4)).astype(np.float32)

        def f(params, x):
            return (x @ params["emb"]) @ params["head"]

        params = {"emb": w, "head": w}
        x = np.ones((2, 4), np.float32)
        res = trace_to_graph(f, params, x)
        # replay on deduped flat inputs
        flat, _ = jax.tree_util.tree_flatten((params, x))
        flat = [v for i, v in enumerate(flat) if i not in res.tied_map]
        out = graph_to_fn(res.graph)(*flat)[0]
        np.testing.assert_allclose(out, f(params, x), rtol=1e-6)


class TestReplay:
    def test_graph_to_fn_matches(self, block_fn, block_args):
        res = trace_to_graph(block_fn, *block_args)
        out = graph_to_fn(res.graph)(*block_args)[0]
        expect = block_fn(*block_args)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)

    def test_shape_dtype_struct_capture(self, block_fn):
        specs = [
            jax.ShapeDtypeStruct(s, jnp.float32)
            for s in [(2, 16, 32), (32, 32), (32, 16), (32, 16), (32, 32),
                      (32, 64), (64,), (64, 32)]
        ]
        res = trace_to_graph(block_fn, *specs)
        assert res.graph.num_nodes() > 10  # abstract capture works
