"""SLO-aware scheduling (ISSUE 9 acceptance criteria): EDF admission,
shed-on-hopeless, page-parking preemption with bitwise resume fidelity,
parked-page accounting under chaos, and the adaptive ladder re-fit."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.paging import TRASH_PAGE, PagePool
from repro.core.shapekey import LadderPolicy, propose_rungs
from repro.launch.serve import BatchedServer, Request, SlotScheduler
from repro.models import get_model
from repro.runtime import chaos
from repro.runtime.chaos import FaultPlan, install_plan


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test starts and ends with no plan installed."""
    prev = install_plan(None)
    yield
    install_plan(prev)


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("forge-125m", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _prompt(n, seed=0, vocab=512):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, (n,)).astype(np.int32)


def _server(cfg, params, *, paged=True, max_len=32):
    return BatchedServer(cfg, params, max_len=max_len, mode="forge",
                         backend="segment_jit",
                         seq_bucket_policy="ladder:8,16,32",
                         paged=paged, kv_page_size=8)


def _bg_plus_burst(vocab, *, bg=2, bg_tokens=24, bursts=2,
                   burst_arrival=4, burst_priority=2, burst_budget=None):
    """Background requests at tick 0 saturating the slots + short
    high-priority bursts arriving mid-decode (tick-clocked arrivals:
    preemption needs queue pressure, not wall deadlines)."""
    reqs = [Request(rid=i, prompt=_prompt(6, seed=i, vocab=vocab),
                    max_new=bg_tokens, priority=0) for i in range(bg)]
    for j in range(bursts):
        reqs.append(Request(rid=100 + j,
                            prompt=_prompt(4, seed=50 + j, vocab=vocab),
                            max_new=3, arrival=burst_arrival + j,
                            priority=burst_priority,
                            ttft_budget_s=burst_budget))
    return reqs


# --------------------------------------------------------------------------
# Ladder re-fit proposal
# --------------------------------------------------------------------------


class TestProposeRungs:
    def test_quantile_fit_covers_top(self):
        obs = [1, 1, 2, 2, 3, 8, 8, 8, 8]
        rungs = propose_rungs(obs, max_rungs=3)
        assert rungs == tuple(sorted(rungs))
        assert rungs[-1] == 8 and 1 <= len(rungs) <= 3
        assert all(r > 0 for r in rungs)

    def test_cap_raises_top_rung(self):
        assert propose_rungs([4, 4, 4], max_rungs=2, cap=16)[-1] == 16
        assert propose_rungs([], cap=16) == (16,)

    def test_single_rung_is_max(self):
        assert propose_rungs([3, 7, 2], max_rungs=1) == (7,)

    def test_errors(self):
        with pytest.raises(ValueError):
            propose_rungs([1, 2], max_rungs=0)
        with pytest.raises(ValueError):
            propose_rungs([])  # no observations and no cap

    def test_rungs_admit_every_observation(self):
        obs = [5, 9, 1, 17, 3, 3, 12]
        pol = LadderPolicy(rungs=propose_rungs(obs, max_rungs=4))
        assert all(pol.bucket(v) >= v for v in obs)


# --------------------------------------------------------------------------
# PagePool park/unpark accounting
# --------------------------------------------------------------------------


class TestPagePark:
    def test_roundtrip_keeps_refs(self):
        pool = PagePool(num_pages=8, page_size=4)
        pages = pool.alloc(3)
        pool.park("r1", pages)
        assert pool.parked_owners == 1 and pool.parked_pages == 3
        pool.check()  # parked pages are reachable, invariants hold
        assert pool.unpark("r1") == pages
        assert pool.parked_owners == 0
        pool.free(pages)
        pool.check()
        assert pool.pages_in_use == 1  # trash pin only
        assert pool.stats.parks == 1 and pool.stats.unparks == 1

    def test_park_rejects_trash_dead_and_double(self):
        pool = PagePool(num_pages=8, page_size=4)
        pages = pool.alloc(2)
        with pytest.raises(ValueError, match="trash"):
            pool.park("r1", [TRASH_PAGE])
        dead = pages[1]
        pool.free([dead])
        with pytest.raises(ValueError, match="dead"):
            pool.park("r1", [dead])
        pool.park("r1", pages[:1])
        with pytest.raises(ValueError):
            pool.park("r1", pages[:1])
        with pytest.raises(KeyError):
            pool.unpark("nobody")

    def test_check_catches_parked_leak(self):
        """Freeing a parked page to refcount 0 breaks reachability —
        check() must refuse the state instead of letting the page be
        reallocated under the parked slot."""
        pool = PagePool(num_pages=8, page_size=4)
        pages = pool.alloc(2)
        pool.park("r1", pages)
        pool.free(pages)
        with pytest.raises(AssertionError):
            pool.check()


# --------------------------------------------------------------------------
# EDF admission + shed
# --------------------------------------------------------------------------


class TestAdmission:
    def test_priority_jumps_queue(self, smoke_setup):
        """With the bucket saturated (pow2 pads max_slots=2 to extent
        2), a later high-priority arrival jumps an earlier equal-class
        one — here by parking a running priority-0 slot."""
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=2)
        reqs = [
            Request(rid=0, prompt=_prompt(6, vocab=cfg.vocab), max_new=16),
            Request(rid=1, prompt=_prompt(6, seed=9, vocab=cfg.vocab),
                    max_new=16),
            Request(rid=2, prompt=_prompt(4, seed=1, vocab=cfg.vocab),
                    max_new=3, arrival=1, priority=0),
            Request(rid=3, prompt=_prompt(4, seed=2, vocab=cfg.vocab),
                    max_new=3, arrival=2, priority=5),
        ]
        sched.warmup(prompt_lens=[4, 6])
        out = sched.run(reqs)
        res = out["results"]
        assert all("error" not in r for r in res.values())
        assert out["preemptions"] >= 1
        # rid 3 (priority 5) jumped rid 2 (earlier, priority 0)
        assert res[3]["admitted_tick"] < res[2]["admitted_tick"]

    def test_edf_budget_orders_queue(self, smoke_setup):
        """Equal-priority queued requests are admitted in deadline
        order, not arrival order: a later-but-tighter TTFT budget wins
        (pure EDF — generous budgets, so nothing sheds or preempts)."""
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=2)
        reqs = [
            Request(rid=0, prompt=_prompt(6, vocab=cfg.vocab), max_new=12),
            Request(rid=1, prompt=_prompt(6, seed=9, vocab=cfg.vocab),
                    max_new=24),
            Request(rid=2, prompt=_prompt(4, seed=1, vocab=cfg.vocab),
                    max_new=3, arrival=1, ttft_budget_s=100.0),
            Request(rid=3, prompt=_prompt(4, seed=2, vocab=cfg.vocab),
                    max_new=3, arrival=2, ttft_budget_s=30.0),
        ]
        sched.warmup(prompt_lens=[4, 6])
        out = sched.run(reqs)
        res = out["results"]
        assert all("error" not in r for r in res.values())
        assert out["preemptions"] == 0 and out["shed"] == 0
        # rid 3's deadline is ~70s earlier than rid 2's
        assert res[3]["admitted_tick"] <= res[2]["admitted_tick"]
        assert res[3]["finished_tick"] < res[2]["finished_tick"]

    def test_hopeless_ttft_is_shed(self, smoke_setup):
        """A queued request whose TTFT deadline already passed is shed
        with a typed RequestError instead of being served late."""
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=2)
        reqs = [
            Request(rid=0, prompt=_prompt(6, vocab=cfg.vocab), max_new=16),
            Request(rid=1, prompt=_prompt(6, seed=9, vocab=cfg.vocab),
                    max_new=16),
            Request(rid=2, prompt=_prompt(4, seed=1, vocab=cfg.vocab),
                    max_new=3, arrival=2, ttft_budget_s=1e-6),
        ]
        sched.warmup(prompt_lens=[4, 6])
        out = sched.run(reqs)
        res = out["results"]
        assert "error" not in res[0] and "error" not in res[1]
        assert res[2]["error_type"] == "RequestError"
        assert "shed" in res[2]["error"]
        assert out["shed"] == 1
        assert out["shed_rate"] == pytest.approx(1 / 3)

    def test_budget_validation(self, smoke_setup):
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=2)
        sched.warmup(prompt_lens=[4])
        out = sched.run([
            Request(rid=0, prompt=_prompt(4, vocab=cfg.vocab), max_new=2,
                    ttft_budget_s=-1.0),
            Request(rid=1, prompt=_prompt(4, vocab=cfg.vocab), max_new=2,
                    latency_budget_s=0.0),
        ])
        assert out["requests_rejected"] == 2
        assert all(r["error_type"] == "RequestError"
                   for r in out["results"].values())

    def test_slo_false_is_throughput_only(self, smoke_setup):
        """slo=False serves the same bursty workload with zero
        preemptions and zero sheds — the explicit FIFO baseline."""
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=2, slo=False)
        reqs = _bg_plus_burst(cfg.vocab, burst_budget=1e-6)
        sched.warmup(prompt_lens=[4, 6])
        out = sched.run(reqs)
        assert all("error" not in r for r in out["results"].values())
        assert out["preemptions"] == 0 and out["shed"] == 0


# --------------------------------------------------------------------------
# Preempt / resume fidelity
# --------------------------------------------------------------------------


class TestPreemptResume:
    def _solo_tokens(self, cfg, params, reqs, *, paged):
        """Fault-free unpreempted reference: same requests, slo off."""
        srv = _server(cfg, params, paged=paged)
        sched = SlotScheduler(srv, max_slots=2, slo=False)
        sched.warmup(prompt_lens=sorted({len(r.prompt) for r in reqs}))
        out = sched.run(reqs)
        assert all("error" not in r for r in out["results"].values())
        return {rid: r["tokens"] for rid, r in out["results"].items()}

    @pytest.mark.parametrize("paged", [True, False],
                             ids=["paged", "contiguous"])
    def test_resume_is_bitwise(self, smoke_setup, paged):
        """A preempted-and-resumed request produces tokens
        bitwise-identical to an unpreempted run: parking keeps the KV
        rows (page refs / pooled row copy) intact and resume re-enters
        them without replaying a single token."""
        cfg, _, params = smoke_setup
        reqs = _bg_plus_burst(cfg.vocab)
        ref = self._solo_tokens(cfg, params, reqs, paged=paged)

        srv = _server(cfg, params, paged=paged)
        sched = SlotScheduler(srv, max_slots=2)
        sched.warmup(prompt_lens=[4, 6])
        out = sched.run(reqs)
        res = out["results"]
        assert all("error" not in r for r in res.values())
        assert out["preemptions"] >= 1 and out["resumes"] >= 1
        preempted = [rid for rid, r in res.items() if r["preempted"]]
        assert preempted, "no request was actually parked"
        for rid, r in res.items():
            np.testing.assert_array_equal(
                r["tokens"], ref[rid],
                err_msg=f"request {rid} diverged after preemption",
            )
        if paged:
            assert srv.page_pool.parked_owners == 0
            srv.page_pool.check()
            srv.prefix_tree.clear()
            assert srv.page_pool.pages_in_use == 1
        else:
            # no ("parked", rid) row trees left behind in the pool
            pool = srv.bucketed.pool
            assert all(pool.pooled(k) == 0 for k in list(pool._free)
                       if isinstance(k, tuple) and k and k[0] == "parked")

    def test_low_priority_never_preempts(self, smoke_setup):
        """Equal-priority queue pressure never parks a running slot."""
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=2)
        reqs = _bg_plus_burst(cfg.vocab, burst_priority=0)
        sched.warmup(prompt_lens=[4, 6])
        out = sched.run(reqs)
        assert all("error" not in r for r in out["results"].values())
        assert out["preemptions"] == 0


# --------------------------------------------------------------------------
# Chaos: faults at/around the park path never leak pages
# --------------------------------------------------------------------------


class TestPreemptChaos:
    def test_park_fault_is_contained(self, smoke_setup):
        """A fault injected at the preemption site raises BEFORE any
        park mutation: the tick fails contained, every request still
        terminates, and page accounting holds."""
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=2)
        reqs = _bg_plus_burst(cfg.vocab)
        sched.warmup(prompt_lens=[4, 6])
        plan = FaultPlan(seed=3).arm(chaos.SITE_PREEMPT, times=(0,))
        prev = install_plan(plan)
        try:
            out = sched.run(reqs)
        finally:
            install_plan(prev)
        assert out["faults_injected"] >= 1
        assert set(out["results"]) == {r.rid for r in reqs}
        srv.page_pool.check()
        assert srv.page_pool.parked_owners == 0
        srv.prefix_tree.clear()
        assert srv.page_pool.pages_in_use == 1

    def test_page_alloc_chaos_never_leaks_parked(self, smoke_setup):
        """Page-alloc faults during a preempt-heavy workload: the run
        finishes, every request terminates with a result, and clearing
        the prefix tree leaves only the trash pin — parked pages are
        never stranded."""
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=2)
        reqs = _bg_plus_burst(cfg.vocab, bursts=3)
        sched.warmup(prompt_lens=[4, 6])
        plan = (FaultPlan(seed=9)
                .arm(chaos.SITE_PAGE_ALLOC, rate=0.25, max_faults=4))
        prev = install_plan(plan)
        try:
            out = sched.run(reqs)
        finally:
            install_plan(prev)
        assert set(out["results"]) == {r.rid for r in reqs}
        srv.page_pool.check()
        assert srv.page_pool.parked_owners == 0
        srv.prefix_tree.clear()
        srv.page_pool.check()
        assert srv.page_pool.pages_in_use == 1


# --------------------------------------------------------------------------
# Adaptive ladder re-fit
# --------------------------------------------------------------------------


class TestRefit:
    def test_refit_matches_unrefit_tokens(self, smoke_setup):
        """Mid-run ladder re-fits change bucket extents, never tokens:
        pad rows are write-inert, so decode is extent-invariant."""
        cfg, _, params = smoke_setup
        reqs = [Request(rid=i, prompt=_prompt(5, seed=i, vocab=cfg.vocab),
                        max_new=10, arrival=i) for i in range(5)]

        srv0 = _server(cfg, params)
        base = SlotScheduler(srv0, max_slots=3)
        base.warmup(prompt_lens=[5])
        ref = base.run(reqs)["results"]

        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=3, refit_interval=4)
        sched.warmup(prompt_lens=[5])
        out = sched.run(reqs)
        assert out["refits"] >= 1
        for rid, r in out["results"].items():
            assert "error" not in r
            np.testing.assert_array_equal(
                r["tokens"], ref[rid]["tokens"],
                err_msg=f"request {rid} diverged across a ladder re-fit",
            )

    def test_refit_pins_policy_name_and_addressability(self, smoke_setup):
        """refit_policy keeps the old policy name so every existing
        AxisKey (programs, pools, disk cache) stays addressable."""
        cfg, _, params = smoke_setup
        srv = _server(cfg, params)
        sched = SlotScheduler(srv, max_slots=3)
        sched.warmup(prompt_lens=[5])
        front = srv.bucketed
        old_name = front.policy.name
        out = sched.run([
            Request(rid=i, prompt=_prompt(5, seed=i, vocab=cfg.vocab),
                    max_new=6, arrival=i) for i in range(4)
        ])
        assert all("error" not in r for r in out["results"].values())
        rungs = sched.refit()
        assert rungs is not None and front.policy.name == old_name
        assert isinstance(front.policy, LadderPolicy)
        # observed extents (<= max_slots) are all admitted by the fit
        assert front.policy.bucket(1) >= 1
        assert sched.top_extent == front.policy.bucket(sched.max_slots)
        assert sched.metrics["refits"] == out["refits"] + 1
