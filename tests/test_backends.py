"""Phase-4 backend layer: registry, segment codegen, compile cache,
executor-stats thread safety (ISSUE 1 acceptance criteria)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompileCache,
    ForgeCompiler,
    PipelineConfig,
    available_backends,
    fingerprint_program,
    forge_compile,
    get_backend,
)
from repro.core.backends import SegmentExecutor
from repro.core.capture import trace_to_graph
from repro.core.executor import analyze_program
from repro.core.lowering import lower_to_rgir
from repro.core.passes import run_forge_passes


def _lowered(fn, *args):
    g = trace_to_graph(fn, *args).graph
    run_forge_passes(g)
    return lower_to_rgir(g)


def _lowered_cfg(fn, cfg, *args):
    g = trace_to_graph(fn, *args).graph
    run_forge_passes(g, cfg=cfg)
    return lower_to_rgir(g)


class TestRegistry:
    def test_builtin_backends_registered(self):
        names = available_backends()
        for expected in ("interpret", "segment_jit", "reference"):
            assert expected in names

    def test_unknown_backend_raises(self):
        """Unknown names raise ValueError (not KeyError) and the message
        lists every registered backend so the fix is self-evident."""
        with pytest.raises(ValueError, match="unknown backend") as ei:
            get_backend("tpu_superfast")
        assert not isinstance(ei.value, KeyError)
        msg = str(ei.value)
        for name in available_backends():
            assert name in msg
        with pytest.raises(ValueError, match="unknown backend"):
            ForgeCompiler(backend="nope")

    def test_config_knob(self, block_fn, block_args):
        mod = forge_compile(block_fn, *block_args, backend="segment_jit")
        assert mod.result.backend == "segment_jit"
        mod2 = ForgeCompiler(PipelineConfig(backend="reference")).compile(
            block_fn, *block_args
        )
        assert mod2.result.backend == "reference"


class TestSegmentBackend:
    def test_matches_interpret_on_block(self, block_fn, block_args):
        """Acceptance: segment_jit ≡ interpret within 1e-5 max-abs."""
        a = forge_compile(block_fn, *block_args, backend="interpret")
        b = forge_compile(block_fn, *block_args, backend="segment_jit")
        diff = np.max(
            np.abs(
                np.asarray(a(*block_args), np.float32)
                - np.asarray(b(*block_args), np.float32)
            )
        )
        assert diff <= 1e-5

    def test_matches_reference_oracle(self, block_fn, block_args):
        from repro.core.metrics import check_backend_fidelity

        reports = check_backend_fidelity(block_fn, *block_args)
        for name, rep in reports.items():
            assert rep.max_abs_diff <= 1e-5, name

    def test_executes_delta_plus_one_segments(self, block_fn, block_args):
        """Acceptance: exactly δ_after + 1 segment dispatches per call."""
        mod = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        s = mod.stats
        mod(*block_args)
        assert s.n_segments == s.delta_after + 1
        assert s.last_segments_executed == s.delta_after + 1
        mod(*block_args)
        assert s.last_segments_executed == s.delta_after + 1
        assert s.total_segments_executed == 2 * (s.delta_after + 1)

    def test_internal_regs_skip_buffer_file(self, block_fn, block_args):
        """Intra-segment temporaries must never occupy physical slots."""
        prog = _lowered(block_fn, *block_args)
        seg_ex = SegmentExecutor(analyze_program(prog))
        assert seg_ex.stats.n_internal_regs > 0
        for r in seg_ex._internal:
            assert r not in seg_ex._r2b
        # segment-aware allocation needs no more slots than plain
        interp = get_backend("interpret").build(prog)
        assert seg_ex.stats.n_buffers <= interp.stats.n_buffers

    def test_segment_live_sets_consistent(self, block_fn, block_args):
        prog = _lowered(block_fn, *block_args)
        ex = SegmentExecutor(analyze_program(prog))
        n = len(ex.prog.ops)
        covered = []
        for seg in ex.segments:
            covered.extend(range(seg.start, seg.stop))
            for i in range(seg.start, seg.stop):
                assert ex.prog.ops[i].device == seg.device
            # live-ins are defined strictly before the segment
            for r in seg.live_in:
                assert ex.live.intervals[r][0] < seg.start
            # live-outs are defined inside and survive past it (or pinned)
            for r in seg.live_out:
                s, e = ex.live.intervals[r]
                assert seg.start <= s < seg.stop
                assert e >= seg.stop or r in ex.live.pinned
        assert covered == list(range(n))

    def test_jit_traceable_and_differentiable(self, block_fn, block_args):
        mod = forge_compile(block_fn, *block_args, backend="segment_jit")
        out = mod.jit()(*block_args)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(block_fn(*block_args), np.float32),
            rtol=1e-3, atol=1e-3,
        )

        def loss(*args):
            return jnp.sum(mod.as_fn()(*args) ** 2)

        def loss_ref(*args):
            return jnp.sum(block_fn(*args) ** 2)

        gx = jax.grad(loss)(*[jnp.asarray(a) for a in block_args])
        gr = jax.grad(loss_ref)(*[jnp.asarray(a) for a in block_args])
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gr),
                                   rtol=2e-2, atol=2e-3)

    def test_forge_125m_model_forward(self):
        """Acceptance target graph: the forge-125m (smoke) block."""
        from repro.configs import get_config
        from repro.models import get_model

        cfg = get_config("forge-125m", smoke=True).with_(
            fuse="none", scan_layers=False, remat=False
        )
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg)
        tokens = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (1, 8)), jnp.int32
        )

        def fwd(params, tokens):
            return model.apply(params, tokens, cfg)

        a = forge_compile(fwd, params, tokens, backend="interpret")
        b = forge_compile(fwd, params, tokens, backend="segment_jit")
        diff = np.max(
            np.abs(
                np.asarray(a(params, tokens), np.float32)
                - np.asarray(b(params, tokens), np.float32)
            )
        )
        assert diff <= 1e-5
        s = b.stats
        b(params, tokens)
        assert s.last_segments_executed == s.delta_after + 1


class TestCompileCache:
    def test_second_compile_hits(self, block_fn, block_args):
        """Acceptance: identical graph -> cache hit, ≥5× lower backend_ms."""
        cache = CompileCache()
        c1 = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=cache
        ).compile(block_fn, *block_args)
        assert not c1.result.cache_hit
        c2 = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=cache
        ).compile(block_fn, *block_args)
        assert c2.result.cache_hit
        assert c2.result.cache_key == c1.result.cache_key
        assert c2.result.backend_ms * 5 <= c1.result.backend_ms
        assert cache.stats.hits == 1 and cache.stats.misses == 1
        # the cached executor is literally the same object
        assert c2.executor is c1.executor

    def test_fingerprint_stable_across_traces(self, block_fn, block_args):
        p1 = _lowered(block_fn, *block_args)
        p2 = _lowered(block_fn, *block_args)
        assert fingerprint_program(p1) == fingerprint_program(p2)

    def test_fingerprint_sensitive_to_literals(self):
        def f3(x):
            return x * 3.0

        def f4(x):
            return x * 4.0

        x = np.ones((4,), np.float32)
        assert fingerprint_program(_lowered(f3, x)) != fingerprint_program(
            _lowered(f4, x)
        )

    def test_fingerprint_sensitive_to_shapes(self):
        def f(x):
            return x @ x

        a = fingerprint_program(_lowered(f, np.ones((4, 4), np.float32)))
        b = fingerprint_program(_lowered(f, np.ones((8, 8), np.float32)))
        assert a != b

    def test_backend_in_key(self, block_fn, block_args):
        cache = CompileCache()
        ForgeCompiler(
            PipelineConfig(backend="interpret"), cache=cache
        ).compile(block_fn, *block_args)
        c2 = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=cache
        ).compile(block_fn, *block_args)
        assert not c2.result.cache_hit  # different backend, different key

    def test_lru_eviction(self):
        cache = CompileCache(max_entries=2)
        cache.put("a", 1)
        cache.put("b", 2)
        cache.put("c", 3)
        assert cache.get("a") is None  # evicted
        assert cache.get("c") == 3
        assert cache.stats.evictions == 1

    def test_tracer_constants_bypass_cache(self):
        """Compiling inside an enclosing trace must not poison the cache:
        closed-over tracers become graph constants with no stable value."""
        from repro.core.cache import UncacheableProgram

        cache = CompileCache()
        seen = {}
        # value-touching passes can't digest tracer constants either, so
        # disable them — the trace-embedded compile path (_forge.py) runs
        # with concrete specs; this exercises the cache guard in isolation
        cfg = PipelineConfig(enable={
            "constant_folding": False, "device_constant": False,
            "cse": False, "layout_optimization": False,
        })

        def outer(w):
            def body(x):
                return x * w  # w is a tracer constant inside this trace

            prog = _lowered_cfg(body, cfg, jax.ShapeDtypeStruct((4,), jnp.float32))
            with pytest.raises(UncacheableProgram):
                fingerprint_program(prog)
            mod = ForgeCompiler(cfg, cache=cache).compile(
                body, jax.ShapeDtypeStruct((4,), jnp.float32)
            )
            seen["key"] = mod.result.cache_key
            return mod.as_fn()(jnp.ones((4,), jnp.float32))

        out = jax.jit(outer)(jnp.asarray(3.0))
        np.testing.assert_allclose(np.asarray(out), 3.0)
        assert seen["key"] is None  # uncacheable -> bypassed
        assert len(cache) == 0

    def test_cache_hit_stats_not_smeared(self, block_fn, block_args):
        """A hit's CompilationResult must not report another module's runs."""
        cache = CompileCache()
        cfg = PipelineConfig(backend="segment_jit")
        a = ForgeCompiler(cfg, cache=cache).compile(block_fn, *block_args)
        for _ in range(3):
            a(*block_args)
        assert a.result.executor_stats.total_segments_executed > 0
        b = ForgeCompiler(cfg, cache=cache).compile(block_fn, *block_args)
        assert b.result.cache_hit
        s = b.result.executor_stats
        assert s.total_segments_executed == 0
        assert s.peak_live_buffers == 0
        assert s.n_segments == a.result.executor_stats.n_segments

    def test_cache_disabled(self, block_fn, block_args):
        c = ForgeCompiler(
            PipelineConfig(compile_cache=False)
        )
        assert c.cache is None
        mod = c.compile(block_fn, *block_args)
        assert mod.result.cache_key is None


class TestExecutorStatsPerCall:
    def test_last_peak_is_per_call(self, block_fn, block_args):
        """Regression: peak tracking must not smear across execute() calls."""
        mod = ForgeCompiler(
            PipelineConfig(backend="interpret"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        mod(*block_args)
        p1 = mod.stats.last_peak_live_buffers
        mod(*block_args)
        p2 = mod.stats.last_peak_live_buffers
        assert p1 == p2 > 0
        assert mod.stats.peak_live_buffers == p1

    def test_thread_safe_updates(self, block_fn, block_args):
        # private cache: the executor (and its stats) must start fresh
        mod = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        errs = []

        def worker():
            try:
                for _ in range(5):
                    mod(*block_args)
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=worker) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        s = mod.stats
        # no lost updates under concurrency: 4 threads x 5 calls
        assert s.total_segments_executed == 20 * s.n_segments

    def test_expected_output_still_correct_under_threads(
        self, block_fn, block_args
    ):
        mod = forge_compile(block_fn, *block_args, backend="segment_jit")
        expect = np.asarray(block_fn(*block_args), np.float32)
        outs = []

        def worker():
            outs.append(np.asarray(mod(*block_args), np.float32))

        threads = [threading.Thread(target=worker) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for o in outs:
            np.testing.assert_allclose(o, expect, rtol=1e-4, atol=1e-4)
