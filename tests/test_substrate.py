"""Substrate tests: optimizers, data pipeline, checkpointing, fault
tolerance, straggler monitor, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenDataset, write_synthetic_corpus
from repro.optim import AdamW, Adafactor, global_norm
from repro.runtime import (
    SimulatedFault,
    StragglerMonitor,
    Supervisor,
    compression_ratio,
    quantize_int8,
)
from repro.runtime.compress import dequantize_int8


class TestOptimizers:
    def _quad_problem(self, opt, steps=60):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros((3,)), "m": jnp.zeros((2, 3))}
        state = opt.init(params)

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

        for _ in range(steps):
            grads = jax.grad(loss)(params)
            params, state = opt.update(grads, state, params)
        return float(loss(params))

    def test_adamw_converges(self):
        final = self._quad_problem(AdamW(lr=0.1, weight_decay=0.0))
        assert final < 0.5

    def test_adafactor_converges(self):
        final = self._quad_problem(Adafactor(lr=0.3), steps=120)
        assert final < 0.5

    def test_adafactor_states_factored(self):
        opt = Adafactor()
        params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
        st = opt.init(params)
        assert st.vr["w"].shape == (8,)
        assert st.vc["w"].shape == (16,)
        assert st.v["w"].shape == ()  # factored: unfactored slot empty
        assert st.v["b"].shape == (16,)  # 1-D: unfactored

    def test_grad_clip(self):
        opt = AdamW(lr=0.0, grad_clip=1.0)
        params = {"w": jnp.zeros((4,))}
        st = opt.init(params)
        big = {"w": jnp.full((4,), 1e6)}
        p2, _ = opt.update(big, st, params)  # lr=0 -> params unchanged
        np.testing.assert_allclose(np.asarray(p2["w"]), 0.0)

    def test_global_norm(self):
        t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        assert float(global_norm(t)) == pytest.approx(5.0)


class TestData:
    def test_deterministic_replay(self):
        cfg = DataConfig(seq_len=16, global_batch=4, vocab=100, seed=7)
        ds = TokenDataset(cfg)
        a = ds.batch(3)
        b = ds.batch(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ds.batch(4)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_shifted(self):
        ds = TokenDataset(DataConfig(seq_len=16, global_batch=2, vocab=100))
        b = ds.batch(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)

    def test_host_sharding(self):
        full = TokenDataset(DataConfig(seq_len=8, global_batch=8, vocab=50))
        h0 = TokenDataset(DataConfig(seq_len=8, global_batch=8, vocab=50,
                                     n_hosts=2, host_id=0))
        h1 = TokenDataset(DataConfig(seq_len=8, global_batch=8, vocab=50,
                                     n_hosts=2, host_id=1))
        assert h0.cfg.host_batch == 4
        b0, b1 = h0.batch(0), h1.batch(0)
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_memmap_corpus(self, tmp_path):
        path = write_synthetic_corpus(str(tmp_path / "c.bin"), 10_000, 100)
        ds = TokenDataset(DataConfig(seq_len=32, global_batch=2, vocab=100,
                                     corpus_path=path))
        b = ds.batch(0)
        assert b["tokens"].shape == (2, 32)
        assert b["tokens"].max() < 100


class TestCheckpoint:
    def _state(self):
        return {
            "params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b16": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(5),
        }

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        state = self._state()
        mgr.save(10, state)
        restored, step = mgr.restore(state)
        assert step == 10
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.arange(6.0).reshape(2, 3)
        )
        assert str(jnp.asarray(restored["params"]["b16"]).dtype) == "bfloat16"

    def test_async_and_keep_last(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2, async_save=True)
        state = self._state()
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        mgr.wait()
        assert mgr.all_steps() == [3, 4]

    def test_atomic_manifest(self, tmp_path):
        """A torn checkpoint dir (no manifest) must be invisible."""
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, self._state())
        os.makedirs(str(tmp_path / "step_0000000002"))  # torn: no MANIFEST
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1

    def test_elastic_restore_sharding_fn(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        state = self._state()
        mgr.save(1, state)
        dev = jax.devices()[0]
        seen = []

        def sharding_fn(path, ex):
            seen.append(path)
            return dev  # device_put target (mesh sharding on real fleets)

        restored, _ = mgr.restore(state, sharding_fn=sharding_fn)
        assert len(seen) == len(jax.tree_util.tree_leaves(state))


class TestSupervisor:
    def test_recovers_from_fault(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        state0 = {"x": jnp.zeros(())}
        mgr.save(0, state0)

        def step_fn(state, batch):
            return {"x": state["x"] + batch}, {"v": float(state["x"])}

        fired = {"done": False}

        def fault(step):
            if step == 7 and not fired["done"]:
                fired["done"] = True
                raise SimulatedFault("boom")

        sup = Supervisor(
            step_fn=step_fn,
            data_fn=lambda s: jnp.asarray(1.0),
            save_fn=mgr.save,
            restore_fn=lambda: mgr.restore(state0),
            checkpoint_every=5,
            fault_hook=fault,
        )
        state, report = sup.run(state0, 0, 12)
        assert report.failures == 1 and report.restores == 1
        # steps 5/6 replayed after restore from step-5 checkpoint
        assert float(state["x"]) == 12.0

    def test_escalates_after_retries(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        state0 = {"x": jnp.zeros(())}
        mgr.save(0, state0)

        sup = Supervisor(
            step_fn=lambda s, b: (_ for _ in ()).throw(RuntimeError("dead")),
            data_fn=lambda s: 1.0,
            save_fn=mgr.save,
            restore_fn=lambda: mgr.restore(state0),
            max_retries=2,
        )
        with pytest.raises(RuntimeError, match="escalating"):
            sup.run(state0, 0, 3)


class TestStraggler:
    def test_detects_straggler(self):
        mon = StragglerMonitor(n_hosts=8, threshold=1.4)
        for _ in range(6):
            times = [1.0] * 8
            times[3] = 2.0  # host 3 is 2x slower
            mon.observe(times)
        assert mon.stragglers() == [3]

    def test_rebalance_sums_to_global(self):
        mon = StragglerMonitor(n_hosts=4)
        for _ in range(6):
            mon.observe([1.0, 1.0, 1.0, 3.0])
        sizes = mon.rebalanced_host_batches(64)
        assert sum(sizes) == 64
        assert sizes[3] < min(sizes[:3])  # slow host gets less work

    def test_no_flag_below_min_samples(self):
        mon = StragglerMonitor(n_hosts=4, min_samples=5)
        mon.observe([1.0, 1.0, 1.0, 9.0])
        assert mon.stragglers() == []


class TestCompression:
    def test_int8_roundtrip_error_small(self, rng):
        x = jnp.asarray(rng.standard_normal((1000,)).astype(np.float32))
        q, s, n = quantize_int8(x)
        deq = dequantize_int8(q, s, n, x.shape, x.dtype)
        err = float(jnp.max(jnp.abs(x - deq)))
        assert err < float(jnp.max(jnp.abs(x))) / 100  # <1% of range

    def test_compression_ratio(self):
        grads = {"w": jnp.zeros((1024, 1024))}
        r = compression_ratio(grads)
        assert 0.4 < r < 0.6  # ~2x vs bf16 wire bytes

    def test_compressed_psum_shard_map(self):
        """compressed_psum inside shard_map equals plain psum (approx)."""
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.runtime import compressed_psum

        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        x = jnp.arange(64.0).reshape(8, 8) / 64.0

        def f(x):
            return compressed_psum(x, "data")

        out = jax.jit(
            shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
        )(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                                   rtol=2e-2, atol=2e-2)
