"""Whole-prompt batched prefill: chunk-causal fidelity vs sequential
decode, the 2-D (batch × sequence) serve grid, and fallback paths
(ISSUE 4 acceptance criteria)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.metrics import check_prefill_fidelity
from repro.launch.serve import BatchedServer
from repro.models import get_model


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("forge-125m", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _prompts(batch, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 512, (batch, n)).astype(np.int32)


class TestPrefillStepFidelity:
    def test_matches_sequential_decode(self, smoke_setup):
        """Acceptance: one prefill_step pass over the (B, P) block
        produces the same per-position logits AND the same KV cache as
        P sequential decode_step calls, within 1e-5."""
        cfg, model, params = smoke_setup
        rep = check_prefill_fidelity(
            cfg, params, _prompts(3, 7), max_len=16
        )
        assert rep.max_abs_diff <= 1e-5

    def test_nonzero_start_position(self, smoke_setup):
        """A chunk written at pos > 0 (e.g. a second prompt segment)
        continues the causal stream exactly."""
        cfg, model, params = smoke_setup
        prompts = _prompts(2, 6, seed=1)
        max_len = 16
        cache_seq = model.init_cache(cfg, 2, max_len)
        for i in range(6):
            _, cache_seq = model.decode_step(
                params, cache_seq, jnp.asarray(prompts[:, i:i + 1]),
                jnp.asarray(i, jnp.int32), cfg,
            )
        cache_b = model.init_cache(cfg, 2, max_len)
        _, cache_b = model.prefill_step(
            params, cache_b, jnp.asarray(prompts[:, :2]),
            jnp.asarray(0, jnp.int32), cfg,
        )
        logits_b, cache_b = model.prefill_step(
            params, cache_b, jnp.asarray(prompts[:, 2:]),
            jnp.asarray(2, jnp.int32), cfg,
        )
        for a, b in zip(jax.tree_util.tree_leaves(cache_seq),
                        jax.tree_util.tree_leaves(cache_b)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=1e-5, rtol=1e-5,
            )

    def test_chunk_mask_is_causal(self, smoke_setup):
        """Perturbing a LATER prompt token must not change any earlier
        position's logits — the chunk-causal length mask at work."""
        cfg, model, params = smoke_setup
        p1 = _prompts(2, 8, seed=2)
        p2 = p1.copy()
        p2[:, -1] = (p2[:, -1] + 7) % cfg.vocab
        cache = model.init_cache(cfg, 2, 16)
        l1, _ = model.prefill_step(
            params, cache, jnp.asarray(p1), jnp.asarray(0, jnp.int32), cfg
        )
        cache = model.init_cache(cfg, 2, 16)
        l2, _ = model.prefill_step(
            params, cache, jnp.asarray(p2), jnp.asarray(0, jnp.int32), cfg
        )
        np.testing.assert_array_equal(
            np.asarray(l1[:, :-1, :]), np.asarray(l2[:, :-1, :])
        )
        assert np.abs(np.asarray(l1[:, -1, :])
                      - np.asarray(l2[:, -1, :])).max() > 0


class TestServePrefillGrid:
    def test_grid_compiles_bounded(self, smoke_setup):
        """Acceptance: the prompt-length sweep {17,32,48,100} × batch
        {1,4} under pow2×ladder compiles ≤ 6 prefill programs (vs 8
        exact cells), all served batched with zero recompiles on the
        repeat pass."""
        cfg, _, params = smoke_setup
        server = BatchedServer(
            cfg, params, max_len=128, mode="forge", backend="interpret",
            bucket_policy="pow2", seq_bucket_policy="ladder:32,64,128",
        )
        groups = [
            _prompts(B, P, seed=B * 100 + P)
            for B in (1, 4) for P in (17, 32, 48, 100)
        ]
        for g in groups:
            res = server.generate(g, 2)
            assert res["prefill_mode"] == "batched"
            assert res["tokens"].shape == (g.shape[0], 2)
        pf = server.prefill_bucketed.stats
        assert pf.compiles <= 6  # vs 8 exact (batch, length) cells
        assert len(server.prefill_bucketed.programs) == pf.compiles
        # every grid cell is warm: the repeat pass runs zero Phase 1-4
        for g in groups:
            assert server.generate(g, 2)["compile_s"] == 0.0
        assert pf.compiles <= 6
        assert pf.pad_waste > 0  # P=17 rode the S32 rung, B=1 rode B2

    def test_batched_matches_sequential_tokens(self, smoke_setup):
        """The batched-prefill server must emit the same greedy tokens
        as the forced-sequential server (same backend, same bucket)."""
        cfg, _, params = smoke_setup
        p = _prompts(3, 9, seed=3)
        batched = BatchedServer(cfg, params, max_len=32, mode="forge",
                                backend="segment_jit")
        seq = BatchedServer(cfg, params, max_len=32, mode="forge",
                            backend="segment_jit", prefill="sequential")
        rb = batched.generate(p, 4)
        rs = seq.generate(p, 4)
        assert rb["prefill_mode"] == "batched"
        assert rs["prefill_mode"] == "sequential"
        assert seq.prefill_bucketed is None
        np.testing.assert_array_equal(rb["tokens"], rs["tokens"])
        assert rb["ttft_s"] > 0 and rs["ttft_s"] > 0

    def test_moe_family_has_no_batched_prefill(self):
        """MoE capacity routing couples tokens across the flattened
        (B, S) block, so whole-prompt prefill would silently diverge
        from sequential decode — the family must expose no prefill_step
        and serve through the sequential path."""
        from repro.launch.steps import make_batched_prefill_step
        from repro.models import transformer

        cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
        assert cfg.family == "moe"
        assert not transformer.supports_batched_prefill(cfg)
        assert get_model(cfg).prefill_step is None
        assert make_batched_prefill_step(cfg) is None
        # direct module callers hit the mechanism-level guard too
        with pytest.raises(NotImplementedError, match="capacity routing"):
            transformer.prefill_step(None, None, None, None, cfg)

    def test_prompt_beyond_ladder_falls_back(self, smoke_setup):
        """A prompt longer than the top sequence rung (or than max_len)
        is admitted through the sequential path, not rejected."""
        cfg, _, params = smoke_setup
        server = BatchedServer(
            cfg, params, max_len=32, mode="forge", backend="interpret",
            seq_bucket_policy="ladder:8",
        )
        res = server.generate(_prompts(2, 12, seed=4), 2)
        assert res["prefill_mode"] == "sequential"
        assert res["tokens"].shape == (2, 2)
        # ... while a prompt inside the ladder still runs batched
        res = server.generate(_prompts(2, 6, seed=5), 2)
        assert res["prefill_mode"] == "batched"
