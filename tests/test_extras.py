"""Extended coverage: RMSNorm kernel, MoE routing, sharded-vocab CE loss,
activation-sharding policy, hypothesis sweep on attention fusion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # optional dep: skips when absent

from repro.kernels import ops, ref
from repro.kernels.rms_norm import rms_norm_pallas
from repro.models import losses
from repro.models.moe import _positions_onehot, _positions_sort, moe_ffn, moe_init


class TestRMSNormKernel:
    @pytest.mark.parametrize("shape", [(4, 64), (2, 16, 128), (3, 5, 32)])
    def test_matches_ref(self, rng, shape):
        x = rng.standard_normal(shape).astype(np.float32)
        w = rng.standard_normal(shape[-1:]).astype(np.float32)
        out = rms_norm_pallas(x, w, interpret=True, block_rows=2)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.rms_norm_ref(x, w)),
            rtol=1e-5, atol=1e-6,
        )

    def test_matches_model_layer(self, rng):
        from repro.models.layers import rms_norm

        x = rng.standard_normal((4, 64)).astype(np.float32)
        w = rng.standard_normal((64,)).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(rms_norm_pallas(x, w, interpret=True)),
            np.asarray(rms_norm(jnp.asarray(x), jnp.asarray(w))),
            rtol=1e-5, atol=1e-6,
        )

    def test_ops_dispatch(self, rng):
        x = rng.standard_normal((8, 32)).astype(np.float32)
        w = np.ones((32,), np.float32)
        a = ops.rms_norm(x, w, impl="interpret")
        b = ops.rms_norm(x, w, impl="xla")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


class TestMoERouting:
    @given(st.integers(2, 12), st.integers(10, 200), st.integers(0, 2**31))
    @settings(max_examples=40, deadline=None)
    def test_sort_equals_onehot(self, n_experts, n, seed):
        rng = np.random.default_rng(seed)
        e = jnp.asarray(rng.integers(0, n_experts, n), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(_positions_sort(e, n_experts)),
            np.asarray(_positions_onehot(e, n_experts)),
        )

    def test_moe_output_impl_invariant(self, rng):
        key = jax.random.PRNGKey(0)
        p = moe_init(key, 16, 32, 4, dtype=jnp.float32)
        x = jnp.asarray(rng.standard_normal((2, 8, 16)).astype(np.float32))
        a = moe_ffn(x, p, n_experts=4, top_k=2, position_impl="sort")
        b = moe_ffn(x, p, n_experts=4, top_k=2, position_impl="onehot")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)

    def test_capacity_drops_tokens(self, rng):
        """Tiny capacity factor must drop (not crash) overflow tokens."""
        key = jax.random.PRNGKey(0)
        p = moe_init(key, 8, 16, 2, dtype=jnp.float32)
        x = jnp.asarray(rng.standard_normal((1, 32, 8)).astype(np.float32))
        out = moe_ffn(x, p, n_experts=2, top_k=2, capacity_factor=0.25)
        assert np.all(np.isfinite(np.asarray(out)))

    def test_moe_grads(self, rng):
        key = jax.random.PRNGKey(0)
        p = moe_init(key, 8, 16, 4, dtype=jnp.float32)
        x = jnp.asarray(rng.standard_normal((1, 8, 8)).astype(np.float32))

        def loss(p):
            return jnp.sum(moe_ffn(x, p, n_experts=4, top_k=2) ** 2)

        g = jax.grad(loss)(p)
        assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
                   for l in jax.tree_util.tree_leaves(g))


class TestShardedVocabLoss:
    def test_matches_naive(self, rng):
        logits = jnp.asarray(rng.standard_normal((4, 16, 33)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 33, (4, 16)), jnp.int32)
        ours = losses.cross_entropy(logits, labels)
        # naive reference
        logp = jax.nn.log_softmax(logits, axis=-1)
        naive = -jnp.mean(
            jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        )
        np.testing.assert_allclose(float(ours), float(naive), rtol=1e-6)

    def test_ignore_id(self, rng):
        logits = jnp.asarray(rng.standard_normal((2, 8, 11)).astype(np.float32))
        labels = jnp.full((2, 8), -1, jnp.int32)
        labels = labels.at[0, 0].set(3)
        loss = losses.cross_entropy(logits, labels, ignore_id=-1)
        # only one token counts
        expect = losses.cross_entropy(logits[:1, :1], labels[:1, :1])
        np.testing.assert_allclose(float(loss), float(expect), rtol=1e-6)

    def test_grad_is_softmax_minus_onehot(self, rng):
        logits = jnp.asarray(rng.standard_normal((1, 4, 7)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 7, (1, 4)), jnp.int32)
        g = jax.grad(lambda l: losses.cross_entropy(l, labels))(logits)
        p = jax.nn.softmax(logits, -1)
        oh = jax.nn.one_hot(labels, 7)
        np.testing.assert_allclose(np.asarray(g), np.asarray((p - oh) / 4),
                                   rtol=1e-4, atol=1e-6)


class TestActivationPolicy:
    def test_noop_without_policy(self, rng):
        from repro.distrib.actsharding import constrain

        x = jnp.ones((4, 4))
        assert constrain(x, "heads") is x

    def test_policy_filters_kinds(self):
        from repro.distrib.actsharding import ActivationPolicy

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        pol = ActivationPolicy(mesh=mesh, only=frozenset({"logits"}))
        assert pol.spec_for("heads", (2, 4, 8, 16)) is None
        assert pol.spec_for("logits", (2, 8, 512)) is not None

    def test_constrain_inside_jit(self):
        from repro.distrib.actsharding import ActivationPolicy, use_policy, constrain

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with use_policy(ActivationPolicy(mesh=mesh)):
            out = jax.jit(lambda x: constrain(x, "tokens") * 2)(
                jnp.ones((2, 4, 8))
            )
        np.testing.assert_allclose(np.asarray(out), 2.0)


class TestAttentionFusionProperty:
    @given(
        st.sampled_from([(1, 2, 1), (2, 4, 2), (1, 4, 4), (1, 8, 2)]),
        st.sampled_from([4, 8, 16]),
        st.sampled_from([8, 16]),
        st.booleans(),
        st.integers(0, 2**31),
    )
    @settings(max_examples=15, deadline=None)
    def test_fusion_preserves_semantics(self, bhk, S, D, causal, seed):
        """Random attention dims: fusion must fire and preserve values."""
        from repro.core.capture import graph_to_fn, trace_to_graph
        from repro.core.passes import run_forge_passes

        B, H, KVH = bhk
        rng = np.random.default_rng(seed)

        def f(q, k, v):
            from jax import lax

            grp = H // KVH
            k2 = jnp.broadcast_to(
                k[:, :, None], (B, KVH, grp, S, D)
            ).reshape(B, H, S, D) if grp > 1 else k
            v2 = jnp.broadcast_to(
                v[:, :, None], (B, KVH, grp, S, D)
            ).reshape(B, H, S, D) if grp > 1 else v
            s = jnp.einsum("bhqd,bhkd->bhqk", q, k2,
                           preferred_element_type=jnp.float32)
            s = s * (1.0 / np.sqrt(D))
            if causal:
                row = lax.broadcasted_iota(jnp.int32, (S, S), 0)
                col = lax.broadcasted_iota(jnp.int32, (S, S), 1)
                s = jnp.where(row >= col, s,
                              jnp.asarray(jnp.finfo(s.dtype).min, s.dtype))
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v2.dtype), v2)

        q = rng.standard_normal((B, H, S, D)).astype(np.float32) * 0.5
        k = rng.standard_normal((B, KVH, S, D)).astype(np.float32) * 0.5
        v = rng.standard_normal((B, KVH, S, D)).astype(np.float32) * 0.5
        g = trace_to_graph(f, q, k, v).graph
        expect = graph_to_fn(g)(q, k, v)[0]
        run_forge_passes(g)
        assert any(n.op == "forge.sdpa" for n in g.nodes.values())
        got = graph_to_fn(g)(q, k, v)[0]
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(expect, np.float32),
                                   rtol=1e-4, atol=1e-5)
