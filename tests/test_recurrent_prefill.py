"""Chunked state-scan prefill for the recurrent families (ISSUE 10):
the associative-scan reformulation of RG-LRU and mLSTM, the Pallas
chunked-scan kernel vs its oracle, chunk-boundary carry chaining, and
chunked ≡ sequential fidelity on the serve paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.metrics import check_prefill_fidelity
from repro.kernels import ops
from repro.kernels.ref import rg_lru_chunk_ref, rg_lru_ref
from repro.kernels.rg_lru import rg_lru_chunked
from repro.launch.serve import BatchedServer, Request, SlotScheduler
from repro.launch.steps import (
    make_batched_prefill_step,
    make_slot_prefill_step,
    supports_batched_prefill,
)
from repro.models import get_model
from repro.models.xlstm import mlstm_chunk_combine

from _hyp import HAVE_HYPOTHESIS, given, settings, st  # optional dep


def _f32(cfg):
    return cfg.with_(dtype="float32")


@pytest.fixture(scope="module")
def rglru_setup():
    cfg = _f32(get_config("recurrentgemma-2b", smoke=True))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


@pytest.fixture(scope="module")
def xlstm_setup():
    cfg = _f32(get_config("xlstm-350m", smoke=True))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _prompts(batch, n, seed=0, vocab=512):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, (batch, n)).astype(np.int32)


def _xa(shape, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    a = rng.uniform(0.3, 0.999, shape).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(a)


def _tree_allclose(got, want, rtol=1e-5, atol=1e-5, path=""):
    if isinstance(want, dict):
        assert set(got) == set(want), path
        for k in want:
            _tree_allclose(got[k], want[k], rtol, atol, f"{path}/{k}")
    elif isinstance(want, (list, tuple)):
        assert len(got) == len(want), path
        for i, (g, w) in enumerate(zip(got, want)):
            _tree_allclose(g, w, rtol, atol, f"{path}[{i}]")
    else:
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=rtol, atol=atol, err_msg=path,
        )


def _tree_bitwise_rows(got, want, rows, path=""):
    """Selected batch rows must survive bitwise (NaN == NaN)."""
    if isinstance(want, dict):
        for k in want:
            _tree_bitwise_rows(got[k], want[k], rows, f"{path}/{k}")
    elif isinstance(want, (list, tuple)):
        for i, (g, w) in enumerate(zip(got, want)):
            _tree_bitwise_rows(g, w, rows, f"{path}[{i}]")
    else:
        for r in rows:
            assert np.array_equal(
                np.asarray(got)[r], np.asarray(want)[r], equal_nan=True
            ), f"{path} row {r} not bitwise-inert"


# --------------------------------------------------------------------------
# Pallas chunked-scan kernel vs the associative_scan oracle
# --------------------------------------------------------------------------


class TestChunkedKernelVsOracle:
    @pytest.mark.parametrize("shape", [(2, 16, 8), (1, 7, 5), (3, 24, 16),
                                       (2, 33, 12)])
    def test_interpret_matches_oracle(self, shape):
        """Acceptance: the Pallas chunked kernel (interpret=True on the
        CPU container) reproduces the pure-associative_scan oracle —
        both the per-step sequence and the h[:, -1] carry output."""
        x, a = _xa(shape, seed=shape[1])
        h0 = jnp.asarray(
            np.random.default_rng(99).standard_normal(
                (shape[0], shape[2])).astype(np.float32))
        h_ref, last_ref = rg_lru_chunk_ref(x, a, h0)
        h, last = rg_lru_chunked(x, a, h0, interpret=True)
        np.testing.assert_allclose(h, h_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(last, last_ref, rtol=1e-5, atol=1e-5)

    def test_block_t_not_dividing_t(self):
        """The carry fold across Pallas T-blocks must be exact even when
        block_t does not divide T (the kernel shrinks the block)."""
        x, a = _xa((2, 13, 8), seed=7)
        h_ref, last_ref = rg_lru_chunk_ref(x, a)
        h, last = rg_lru_chunked(x, a, block_t=8, interpret=True)
        np.testing.assert_allclose(h, h_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(last, last_ref, rtol=1e-5, atol=1e-5)

    def test_ops_dispatch(self):
        """ops.rg_lru_scan routes xla → oracle, interpret → kernel, and
        both return the (h, h_last) pair."""
        x, a = _xa((1, 9, 4), seed=3)
        h_x, last_x = ops.rg_lru_scan(x, a, impl="xla")
        h_i, last_i = ops.rg_lru_scan(x, a, impl="interpret")
        np.testing.assert_allclose(h_i, h_x, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(last_i, last_x, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(last_x, h_x[:, -1], rtol=0, atol=0)

    def test_grad_matches_reference(self):
        """custom_vjp: grads through BOTH outputs match the oracle's."""
        x, a = _xa((2, 11, 6), seed=5)
        h0 = jnp.asarray(np.random.default_rng(6).standard_normal(
            (2, 6)).astype(np.float32))

        def loss_k(x, a, h0):
            h, last = rg_lru_chunked(x, a, h0, interpret=True)
            return jnp.sum(h * h) + jnp.sum(last)

        def loss_r(x, a, h0):
            h, last = rg_lru_chunk_ref(x, a, h0)
            return jnp.sum(h * h) + jnp.sum(last)

        gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, a, h0)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, a, h0)
        for g1, g2 in zip(gk, gr):
            np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# chunk-boundary carry: chaining chunks == one unchunked scan
# --------------------------------------------------------------------------


class TestChunkBoundaryCarry:
    @pytest.mark.parametrize("chunk", [4, 5, 16])  # 4 | 16; 5 ∤ 16; whole
    def test_chained_chunks_match_unchunked(self, chunk):
        """Folding h_last into the next chunk's h0 reproduces the
        single-scan result for chunk sizes dividing and not dividing S."""
        x, a = _xa((2, 16, 8), seed=chunk)
        want = rg_lru_ref(x, a)
        h0 = None
        got = []
        for s in range(0, 16, chunk):
            h, h0 = ops.rg_lru_scan(
                x[:, s:s + chunk], a[:, s:s + chunk], h0, impl="interpret"
            )
            got.append(h)
        np.testing.assert_allclose(
            jnp.concatenate(got, axis=1), want, rtol=1e-5, atol=1e-5
        )

    def test_mlstm_chunk_scan_chained(self):
        """mlstm_chunk_scan carried across a chunk boundary equals the
        token-by-token recurrent decode."""
        from repro.models.xlstm import mlstm_chunk_scan, mlstm_recurrent_step

        B, H, S, D = 2, 3, 11, 4
        rng = np.random.default_rng(11)
        q, k, v = (jnp.asarray(rng.standard_normal(
            (B, H, S, D)).astype(np.float32)) for _ in range(3))
        i_pre = jnp.asarray(rng.standard_normal((B, H, S)).astype(np.float32))
        f_pre = jnp.asarray(
            rng.standard_normal((B, H, S)).astype(np.float32) + 3.0)
        state = {
            "C": jnp.zeros((B, H, D, D), jnp.float32),
            "n": jnp.zeros((B, H, D), jnp.float32),
            "m": jnp.zeros((B, H), jnp.float32) - 1e30,
        }
        # sequential reference
        st = state
        hs = []
        for t in range(S):
            h, st = mlstm_recurrent_step(
                q[:, :, t], k[:, :, t], v[:, :, t],
                i_pre[:, :, t], f_pre[:, :, t], st,
            )
            hs.append(h)
        want = jnp.stack(hs, axis=2)
        # chunked: 11 = 4 + 7 (boundary not at a power of two)
        st2 = state
        got = []
        for s, e in ((0, 4), (4, 11)):
            L = jnp.full((B,), e - s, jnp.int32)
            h, st2 = mlstm_chunk_scan(
                q[:, :, s:e], k[:, :, s:e], v[:, :, s:e],
                i_pre[:, :, s:e], f_pre[:, :, s:e], st2, L,
            )
            got.append(h)
        np.testing.assert_allclose(
            jnp.concatenate(got, axis=2), want, rtol=1e-5, atol=1e-5
        )
        _tree_allclose(st2, st)


# --------------------------------------------------------------------------
# associativity property (hypothesis when installed; a fixed-seed sweep
# keeps the invariant asserted — with no skip — when it is absent)
# --------------------------------------------------------------------------


def _check_rg_lru_assoc(seed):
    """(a1,x1)∘(a2,x2) = (a1·a2, a2·x1+x2) must associate — the
    precondition for lax.associative_scan to be a valid evaluation
    order for the affine recurrence."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.2, 0.999, (3, 5)).astype(np.float64)
    x = rng.standard_normal((3, 5)).astype(np.float64)

    def comb(e1, e2):
        return e1[0] * e2[0], e2[0] * e1[1] + e2[1]

    e = [(a[i], x[i]) for i in range(3)]
    lhs = comb(comb(e[0], e[1]), e[2])
    rhs = comb(e[0], comb(e[1], e[2]))
    np.testing.assert_allclose(lhs[0], rhs[0], rtol=1e-12)
    np.testing.assert_allclose(lhs[1], rhs[1], rtol=1e-12, atol=1e-12)


def _check_mlstm_assoc(seed):
    """The stabilized (F, M, Ĉ, n̂) combine must associate — max/+
    distribute, so grouping cannot change the folded cell."""
    rng = np.random.default_rng(seed)

    def elem(i):  # noqa: ARG001 — rng advances per element
        F = jnp.asarray(-np.abs(rng.standard_normal((2,))))
        M = jnp.asarray(rng.standard_normal((2,)) * 3)
        C = jnp.asarray(rng.standard_normal((2, 3, 3)))
        n = jnp.asarray(rng.standard_normal((2, 3)))
        return F, M, C, n

    e0, e1, e2 = elem(0), elem(1), elem(2)
    lhs = mlstm_chunk_combine(mlstm_chunk_combine(e0, e1), e2)
    rhs = mlstm_chunk_combine(e0, mlstm_chunk_combine(e1, e2))
    for g1, g2 in zip(lhs, rhs):
        np.testing.assert_allclose(
            np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-6
        )


class TestCombineAssociativity:
    def test_rg_lru_combine_associative(self):
        for seed in range(20):
            _check_rg_lru_assoc(seed)

    def test_mlstm_combine_associative(self):
        for seed in range(20):
            _check_mlstm_assoc(seed)

    if HAVE_HYPOTHESIS:
        @given(st.integers(min_value=0, max_value=10_000))
        @settings(max_examples=25, deadline=None)
        def test_rg_lru_combine_associative_prop(self, seed):
            _check_rg_lru_assoc(seed)

        @given(st.integers(min_value=0, max_value=10_000))
        @settings(max_examples=25, deadline=None)
        def test_mlstm_combine_associative_prop(self, seed):
            _check_mlstm_assoc(seed)


# --------------------------------------------------------------------------
# model-level: chunked prefill ≡ sequential decode (both families)
# --------------------------------------------------------------------------


def _sequential_reference(model, cfg, params, prompts, max_len=32, pos0=0):
    B, P = prompts.shape
    cache = model.init_cache(cfg, B, max_len)
    logits = []
    for t in range(P):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray(prompts[:, t:t + 1], jnp.int32),
            jnp.full((B,), pos0 + t, jnp.int32), cfg,
        )
        logits.append(lg[:, -1, :])
    return jnp.stack(logits, axis=1), cache


class TestRglruChunkedPrefill:
    def test_matches_sequential(self, rglru_setup):
        """Acceptance: one chunked prefill pass == P decode steps —
        logits and every cache leaf (h, conv, rotating window KV)."""
        cfg, model, params = rglru_setup
        rep = check_prefill_fidelity(
            cfg, params, _prompts(2, 13, seed=1, vocab=cfg.vocab),
            max_len=32,
        )
        assert rep.max_abs_diff <= 1e-5, rep.max_abs_diff

    def test_nonzero_position_past_window(self, rglru_setup):
        """A second prompt segment prefilled at pos > 0, long enough
        that the rotating window wraps (P > window): the continuation
        must match decoding the segment token-by-token."""
        cfg, model, params = rglru_setup
        assert cfg.window and cfg.window < 13
        p1 = _prompts(2, 6, seed=2, vocab=cfg.vocab)
        p2 = _prompts(2, 13, seed=3, vocab=cfg.vocab)
        B = 2
        # sequential over both segments
        cache_s = model.init_cache(cfg, B, 64)
        for t in range(6):
            _, cache_s = model.decode_step(
                params, cache_s, jnp.asarray(p1[:, t:t + 1], jnp.int32),
                jnp.full((B,), t, jnp.int32), cfg)
        logits_seq = []
        for t in range(13):
            lg, cache_s = model.decode_step(
                params, cache_s, jnp.asarray(p2[:, t:t + 1], jnp.int32),
                jnp.full((B,), 6 + t, jnp.int32), cfg)
            logits_seq.append(lg[:, -1, :])
        # chunked: segment 1 chunked at pos 0, segment 2 chunked at pos 6
        cache_c = model.init_cache(cfg, B, 64)
        _, cache_c = model.prefill_step(
            params, cache_c, jnp.asarray(p1, jnp.int32),
            jnp.zeros((B,), jnp.int32), cfg)
        logits_c, cache_c = model.prefill_step(
            params, cache_c, jnp.asarray(p2, jnp.int32),
            jnp.full((B,), 6, jnp.int32), cfg)
        np.testing.assert_allclose(
            logits_c, jnp.stack(logits_seq, 1), rtol=1e-5, atol=1e-5)
        _tree_allclose(cache_c, cache_s)

    def test_ragged_lengths(self, rglru_setup):
        """Per-row length: each row's carried state must equal its OWN
        length-step sequential state, not the padded chunk width's."""
        cfg, model, params = rglru_setup
        prompts = _prompts(2, 13, seed=4, vocab=cfg.vocab)
        _, cache5 = _sequential_reference(
            model, cfg, params, prompts[:, :5], max_len=32)
        _, cache13 = _sequential_reference(
            model, cfg, params, prompts, max_len=32)
        cache = model.init_cache(cfg, 2, 32)
        _, cache = model.prefill_step(
            params, cache, jnp.asarray(prompts, jnp.int32),
            jnp.zeros((2,), jnp.int32), cfg,
            length=jnp.asarray([5, 13], jnp.int32))
        got0 = jax.tree_util.tree_map(lambda x: np.asarray(x)[0], cache)
        want0 = jax.tree_util.tree_map(lambda x: np.asarray(x)[0], cache5)
        _tree_allclose(got0, want0)
        got1 = jax.tree_util.tree_map(lambda x: np.asarray(x)[1], cache)
        want1 = jax.tree_util.tree_map(lambda x: np.asarray(x)[1], cache13)
        _tree_allclose(got1, want1)

    def test_masked_slots_nan_inert(self, rglru_setup):
        """A slot-masked row's state survives bitwise — even when it
        holds NaN — and its garbage never reaches active rows."""
        cfg, model, params = rglru_setup
        prompts = _prompts(2, 9, seed=5, vocab=cfg.vocab)
        logits_seq, _ = _sequential_reference(
            model, cfg, params, prompts, max_len=32)
        cache = jax.tree_util.tree_map(
            lambda x: (x.at[0].set(jnp.nan)
                       if jnp.issubdtype(x.dtype, jnp.floating) else x),
            model.init_cache(cfg, 2, 32))
        logits, new_cache = model.prefill_step(
            params, cache, jnp.asarray(prompts, jnp.int32),
            jnp.zeros((2,), jnp.int32), cfg,
            slot_mask=jnp.asarray([False, True]))
        _tree_bitwise_rows(new_cache, cache, rows=[0])
        assert bool(jnp.all(jnp.isfinite(logits[1])))
        np.testing.assert_allclose(
            logits[1], logits_seq[1], rtol=1e-5, atol=1e-5)


class TestXlstmChunkedPrefill:
    def test_matches_sequential(self, xlstm_setup):
        """Acceptance: chunked mLSTM scan + in-program sLSTM scan == P
        decode steps (logits ≤ 1e-5; states allclose — the reordered
        f32 reduction shifts the last bit of deep-layer normalizers)."""
        cfg, model, params = xlstm_setup
        prompts = _prompts(2, 13, seed=6, vocab=cfg.vocab)
        logits_seq, cache_seq = _sequential_reference(
            model, cfg, params, prompts, max_len=32)
        cache = model.init_cache(cfg, 2, 32)
        logits, cache = model.prefill_step(
            params, cache, jnp.asarray(prompts, jnp.int32),
            jnp.zeros((2,), jnp.int32), cfg)
        np.testing.assert_allclose(
            logits, logits_seq, rtol=1e-5, atol=1e-5)
        _tree_allclose(cache, cache_seq)

    def test_ragged_lengths(self, xlstm_setup):
        cfg, model, params = xlstm_setup
        prompts = _prompts(2, 11, seed=7, vocab=cfg.vocab)
        _, cache4 = _sequential_reference(
            model, cfg, params, prompts[:, :4], max_len=32)
        _, cache11 = _sequential_reference(
            model, cfg, params, prompts, max_len=32)
        cache = model.init_cache(cfg, 2, 32)
        _, cache = model.prefill_step(
            params, cache, jnp.asarray(prompts, jnp.int32),
            jnp.zeros((2,), jnp.int32), cfg,
            length=jnp.asarray([4, 11], jnp.int32))
        got0 = jax.tree_util.tree_map(lambda x: np.asarray(x)[0], cache)
        want0 = jax.tree_util.tree_map(lambda x: np.asarray(x)[0], cache4)
        _tree_allclose(got0, want0)
        got1 = jax.tree_util.tree_map(lambda x: np.asarray(x)[1], cache)
        want1 = jax.tree_util.tree_map(lambda x: np.asarray(x)[1], cache11)
        _tree_allclose(got1, want1)

    def test_masked_slots_nan_inert(self, xlstm_setup):
        cfg, model, params = xlstm_setup
        prompts = _prompts(2, 9, seed=8, vocab=cfg.vocab)
        logits_seq, _ = _sequential_reference(
            model, cfg, params, prompts, max_len=32)
        cache = jax.tree_util.tree_map(
            lambda x: (x.at[0].set(jnp.nan)
                       if jnp.issubdtype(x.dtype, jnp.floating) else x),
            model.init_cache(cfg, 2, 32))
        logits, new_cache = model.prefill_step(
            params, cache, jnp.asarray(prompts, jnp.int32),
            jnp.zeros((2,), jnp.int32), cfg,
            slot_mask=jnp.asarray([False, True]))
        _tree_bitwise_rows(new_cache, cache, rows=[0])
        assert bool(jnp.all(jnp.isfinite(logits[1])))
        np.testing.assert_allclose(
            logits[1], logits_seq[1], rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# serve integration: the unified predicate + both fronts
# --------------------------------------------------------------------------


class TestServeIntegration:
    def test_supports_batched_prefill_predicate(self, rglru_setup,
                                                xlstm_setup):
        """The single serve-front predicate now admits the recurrent
        families (and the step builders follow it)."""
        for cfg, model, _ in (rglru_setup, xlstm_setup):
            assert supports_batched_prefill(cfg)
            assert model.prefill_takes_length
            assert make_batched_prefill_step(cfg) is not None
            assert make_slot_prefill_step(cfg) is not None
        moe = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
        assert not supports_batched_prefill(moe)
        assert make_slot_prefill_step(moe) is None

    @pytest.mark.parametrize("name", ["recurrentgemma-2b", "xlstm-350m"])
    def test_generate_chunked_matches_sequential(self, name):
        """BatchedServer end-to-end: the chunked grid prefill emits the
        same greedy tokens as the forced sequential fill, and reports
        last_prefill_mode == 'chunked'."""
        cfg = get_config(name, smoke=True)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(1), cfg)
        prompts = _prompts(2, 13, seed=9, vocab=cfg.vocab)
        srv = BatchedServer(cfg, params, max_len=64, mode="forge",
                            backend="interpret")
        res = srv.generate(prompts, 5)
        assert res["prefill_mode"] == "chunked"
        srv_seq = BatchedServer(cfg, params, max_len=64, mode="forge",
                                backend="interpret", prefill="sequential")
        res_seq = srv_seq.generate(prompts, 5)
        assert res_seq["prefill_mode"] == "sequential"
        np.testing.assert_array_equal(res["tokens"], res_seq["tokens"])

    def test_scheduler_swap_in_through_chunked_grid(self):
        """SlotScheduler on a recurrent family now admits through the
        slot-masked chunked prefill (prefill_dispatches > 0) with exact
        token fidelity — the in-loop masked-fill replay is retired to
        the ``--prefill sequential`` fallback."""
        cfg = get_config("xlstm-350m", smoke=True)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(3), cfg)
        server = BatchedServer(cfg, params, max_len=32, mode="forge",
                               backend="interpret")
        sched = SlotScheduler(server, max_slots=2)
        sched.warmup()

        def _p(n, seed):
            return _prompts(1, n, seed=seed, vocab=cfg.vocab)[0]

        reqs = [
            Request(rid=0, prompt=_p(3, 30), max_new=6),
            Request(rid=1, prompt=_p(5, 31), max_new=2),
            Request(rid=2, prompt=_p(4, 32), max_new=3, arrival=1),
        ]
        out = sched.run(reqs)
        assert out["prefill_dispatches"] > 0
        assert out["swaps"] >= 1
        solo = BatchedServer(cfg, params, max_len=32, mode="forge",
                             backend="interpret")
        for r in reqs:
            want = solo.generate(r.prompt[None, :], r.max_new)["tokens"][0]
            np.testing.assert_array_equal(
                out["results"][r.rid]["tokens"], want)
