"""Phase 3+4 tests: lowering, liveness, linear-scan allocation, scheduling,
executor — unit + hypothesis property tests on the invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # optional dep: skips when absent

from repro.core.bufalloc import allocate, validate_allocation, allocate_from_liveness
from repro.core.capture import trace_to_graph
from repro.core.executor import CompiledExecutor, build_executor
from repro.core.liveness import LivenessInfo, analyze_liveness
from repro.core.lowering import RegRef, lower_to_rgir, route_device
from repro.core.passes import run_forge_passes
from repro.core.scheduler import schedule, verify_topological


def lowered(fn, *args, optimize=True):
    g = trace_to_graph(fn, *args).graph
    if optimize:
        run_forge_passes(g)
    return g, lower_to_rgir(g)


class TestLowering:
    def test_device_routing(self):
        assert route_device("forge.sdpa") == "accel"
        assert route_device("dot_general") == "accel"
        assert route_device("add") == "host"

    def test_structure(self, block_fn, block_args):
        g, prog = lowered(block_fn, *block_args)
        assert len(prog.ops) == g.num_nodes()
        assert len(prog.input_regs) == len(g.invars)
        # every RegRef must point at a defined register
        defined = set(prog.input_regs) | set(prog.constants)
        for op in prog.ops:
            for a in op.frozen_args:
                if isinstance(a, RegRef):
                    assert a.reg in defined, f"undefined reg {a.reg}"
            defined.update(op.output_regs)
        assert all(r in defined for r in prog.output_regs)

    def test_frozen_literals(self):
        def f(x):
            return x * 3.0

        g, prog = lowered(f, np.ones((4,), np.float32), optimize=False)
        op = prog.ops[0]
        lits = [a for a in op.frozen_args if not isinstance(a, RegRef)]
        assert len(lits) == 1 and float(lits[0]) == 3.0

    def test_unused_consts_dropped(self):
        def f(x):
            dead_const = jnp.arange(128.0)  # folded then dead
            return x + 1.0 + dead_const[0] * 0.0

        g = trace_to_graph(f, np.float32(2.0)).graph
        run_forge_passes(g)
        prog = lower_to_rgir(g)
        # all loaded constants must actually be referenced
        used = set()
        for op in prog.ops:
            for a in op.frozen_args:
                if isinstance(a, RegRef):
                    used.add(a.reg)
        used.update(prog.output_regs)
        assert set(prog.constants) <= used


class TestLiveness:
    def test_intervals(self, block_fn, block_args):
        _, prog = lowered(block_fn, *block_args)
        live = analyze_liveness(prog)
        n = len(prog.ops)
        for r, (s, e) in live.intervals.items():
            assert -1 <= s <= n and s <= e <= n
        # dead_after never frees outputs
        for regs in live.dead_after.values():
            assert not (set(regs) & set(prog.output_regs))

    def test_dead_after_is_last_use(self, block_fn, block_args):
        _, prog = lowered(block_fn, *block_args)
        live = analyze_liveness(prog)
        for idx, regs in live.dead_after.items():
            for r in regs:
                # r must not be read by any later instruction
                for later in prog.ops[idx + 1:]:
                    assert r not in later.input_regs


class TestLinearScan:
    def test_reduction_on_real_graph(self, block_fn, block_args):
        _, prog = lowered(block_fn, *block_args)
        live = analyze_liveness(prog)
        alloc = allocate_from_liveness(live)
        assert alloc.n_buffers < alloc.n_vregs
        validate_allocation(alloc, live)

    @given(
        st.lists(
            st.tuples(st.integers(0, 60), st.integers(0, 30)),
            min_size=1, max_size=80,
        )
    )
    @settings(max_examples=200, deadline=None)
    def test_property_no_double_booking(self, raw):
        """Linear scan never assigns overlapping intervals to one buffer."""
        lifetimes = {
            i: (s, s + d) for i, (s, d) in enumerate(raw)
        }
        alloc = allocate(lifetimes, pinned=set())
        live = LivenessInfo(intervals=lifetimes, dead_after={}, pinned=set())
        validate_allocation(alloc, live)

    @given(
        st.lists(
            st.tuples(st.integers(0, 40), st.integers(0, 20)),
            min_size=2, max_size=60,
        ),
        st.sets(st.integers(0, 59)),
    )
    @settings(max_examples=100, deadline=None)
    def test_property_pinned_dedicated(self, raw, pinned_idx):
        lifetimes = {i: (s, s + d) for i, (s, d) in enumerate(raw)}
        pinned = {i for i in pinned_idx if i in lifetimes}
        alloc = allocate(lifetimes, pinned=pinned)
        # pinned regs never share their buffer with anyone
        bufs = {}
        for r, b in alloc.reg_to_buf.items():
            bufs.setdefault(b, []).append(r)
        for r in pinned:
            assert len(bufs[alloc.reg_to_buf[r]]) == 1


class TestScheduler:
    def test_reduces_transitions(self, block_fn, block_args):
        _, prog = lowered(block_fn, *block_args)
        res = schedule(prog)
        assert res.delta_after <= res.delta_before
        verify_topological(prog, res.order)

    def test_permutation_valid(self, block_fn, block_args):
        _, prog = lowered(block_fn, *block_args)
        res = schedule(prog)
        assert sorted(res.order) == list(range(len(prog.ops)))

    @given(st.data())
    @settings(max_examples=50, deadline=None)
    def test_property_random_dag_topological(self, data):
        """Scheduling any random primitive DAG preserves dependencies."""
        n = data.draw(st.integers(2, 12))
        rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))

        def f(x):
            vals = [x]
            for i in range(n):
                a = vals[int(rng.integers(0, len(vals)))]
                b = vals[int(rng.integers(0, len(vals)))]
                op = int(rng.integers(0, 3))
                if op == 0:
                    vals.append(a + b)
                elif op == 1:
                    vals.append(a * 0.5 + jnp.tanh(b))
                else:
                    vals.append(a @ b)
            return vals[-1]

        g = trace_to_graph(f, np.ones((4, 4), np.float32)).graph
        prog = lower_to_rgir(g)
        res = schedule(prog)
        verify_topological(prog, res.order)


class TestExecutor:
    def test_matches_reference(self, block_fn, block_args):
        g = trace_to_graph(block_fn, *block_args).graph
        run_forge_passes(g)
        ex = build_executor(g)
        out = ex.execute(*block_args)[0]
        expect = block_fn(*block_args)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            rtol=1e-4, atol=1e-4,
        )

    def test_reorder_equivalence(self, block_fn, block_args):
        """Scheduled vs unscheduled execution must agree exactly."""
        g = trace_to_graph(block_fn, *block_args).graph
        run_forge_passes(g)
        a = build_executor(g, reorder=True).execute(*block_args)[0]
        b = build_executor(g, reorder=False).execute(*block_args)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_stats(self, block_fn, block_args):
        g = trace_to_graph(block_fn, *block_args).graph
        run_forge_passes(g)
        ex = build_executor(g)
        s = ex.stats
        assert s.n_vregs > s.n_buffers
        assert 0.0 < s.rho_buf < 1.0
        assert s.delta_after <= s.delta_before
        assert s.n_accel + s.n_host == s.n_instructions

    def test_jit_mode(self, block_fn, block_args):
        g = trace_to_graph(block_fn, *block_args).graph
        run_forge_passes(g)
        ex = build_executor(g)
        out = jax.jit(lambda *a: ex.as_fn()(*a))(*block_args)[0]
        expect = block_fn(*block_args)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            rtol=1e-3, atol=1e-3,
        )

    def test_differentiable(self, block_fn, block_args):
        g = trace_to_graph(block_fn, *block_args).graph
        run_forge_passes(g)
        ex = build_executor(g)

        def loss(*args):
            return jnp.sum(ex.as_fn()(*args)[0] ** 2)

        def loss_ref(*args):
            return jnp.sum(block_fn(*args) ** 2)

        gx = jax.grad(loss)(*[jnp.asarray(a) for a in block_args])
        gr = jax.grad(loss_ref)(*[jnp.asarray(a) for a in block_args])
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gr),
                                   rtol=2e-2, atol=2e-3)
