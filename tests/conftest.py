"""Shared fixtures for the Forge-UGC test suite.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py fakes 512.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)


def make_block_fn():
    """A GQA transformer block written UNFUSED (the capture target)."""

    def block(x, wq, wk, wv, wo, w1, b1, w2):
        B, S, E = x.shape
        H, D = 4, E // 4
        KVH = 2
        q = (x @ wq).reshape(B, S, H, D).transpose(0, 2, 1, 3)
        k = (x @ wk).reshape(B, S, KVH, D).transpose(0, 2, 1, 3)
        v = (x @ wv).reshape(B, S, KVH, D).transpose(0, 2, 1, 3)
        g = H // KVH
        k = jnp.broadcast_to(k[:, :, None], (B, KVH, g, S, D)).reshape(B, H, S, D)
        v = jnp.broadcast_to(v[:, :, None], (B, KVH, g, S, D)).reshape(B, H, S, D)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
        s = s * (1.0 / np.sqrt(D))
        row = lax.broadcasted_iota(jnp.int32, (S, S), 0)
        col = lax.broadcasted_iota(jnp.int32, (S, S), 1)
        s = jnp.where(row >= col, s, jnp.asarray(jnp.finfo(s.dtype).min, s.dtype))
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, E)
        o = o @ wo
        x = x + o
        h = jax.nn.silu(x @ w1 + b1)
        return x + h @ w2

    return block


def make_block_args(rng, B=2, S=16, E=32, F=64, scale=0.1, dtype=np.float32):
    shapes = [(B, S, E), (E, E), (E, E // 2), (E, E // 2), (E, E),
              (E, F), (F,), (F, E)]
    return [rng.standard_normal(s).astype(dtype) * scale for s in shapes]


@pytest.fixture(scope="session")
def block_fn():
    return make_block_fn()


@pytest.fixture()
def block_args(rng):
    return make_block_args(rng)
