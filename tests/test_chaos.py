"""Chaos soak for the serving stack (ISSUE 8 acceptance criteria).

Under a seeded :class:`FaultPlan` covering every injection site, the
slot-scheduler loop must never crash: every request terminates with a
typed outcome, requests untouched by faults produce tokens
bitwise-identical to a fault-free run, and page/slot accounting
invariants hold afterwards.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import BatchedServer, Request, SlotScheduler
from repro.models import get_model
from repro.runtime.chaos import (
    ALL_SITES,
    SITE_COMPILE_BUILD,
    SITE_DISK_CORRUPT,
    SITE_DISK_READ,
    SITE_DISK_WRITE,
    SITE_DISPATCH,
    SITE_LOGITS_NAN,
    SITE_PAGE_ALLOC,
    FaultPlan,
    InjectedFault,
    SystemError_,
    current_plan,
    install_plan,
    plan_from_spec,
)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test starts and ends with no plan installed."""
    prev = install_plan(None)
    yield
    install_plan(prev)


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("forge-125m", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _tokens(n, seed=0, vocab=512):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, (n,)).astype(np.int32)


# --------------------------------------------------------------------------
# FaultPlan semantics
# --------------------------------------------------------------------------


class TestFaultPlan:
    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultPlan().arm("no.such.site", rate=0.5)

    def test_same_seed_same_schedule(self):
        a = FaultPlan(seed=3).arm(SITE_DISPATCH, rate=0.3)
        b = FaultPlan(seed=3).arm(SITE_DISPATCH, rate=0.3)
        pa = [a.check(SITE_DISPATCH) for _ in range(200)]
        pb = [b.check(SITE_DISPATCH) for _ in range(200)]
        assert pa == pb and any(pa)
        assert a.log == b.log

    def test_site_streams_are_independent(self):
        """Interleaving calls at OTHER sites never perturbs a site's own
        fault schedule — determinism survives cross-site reordering."""
        a = FaultPlan(seed=5).arm(SITE_DISPATCH, rate=0.3)
        b = (FaultPlan(seed=5).arm(SITE_DISPATCH, rate=0.3)
             .arm(SITE_DISK_READ, rate=0.9))
        pa, pb = [], []
        for k in range(100):
            pa.append(a.check(SITE_DISPATCH))
            b.check(SITE_DISK_READ)  # extra traffic on another site
            pb.append(b.check(SITE_DISPATCH))
            b.check(SITE_DISK_READ)
        assert pa == pb

    def test_times_every_and_max_faults(self):
        p = FaultPlan().arm(SITE_DISPATCH, times=(1, 4))
        assert [p.check(SITE_DISPATCH) for _ in range(6)] == \
            [False, True, False, False, True, False]
        p = FaultPlan().arm(SITE_DISPATCH, every=3)
        assert [p.check(SITE_DISPATCH) for _ in range(7)] == \
            [False, False, True, False, False, True, False]
        p = FaultPlan().arm(SITE_DISPATCH, every=2, max_faults=2)
        fired = [p.check(SITE_DISPATCH) for _ in range(10)]
        assert sum(fired) == 2 and p.fired(SITE_DISPATCH) == 2
        assert p.calls(SITE_DISPATCH) == 10

    def test_install_returns_previous_and_hooks_are_inert_without_plan(self):
        from repro.runtime.chaos import maybe_fault, should_fault

        assert current_plan() is None
        assert should_fault(SITE_DISPATCH) is False
        maybe_fault(SITE_DISPATCH)  # no plan: never raises
        p1 = FaultPlan()
        assert install_plan(p1) is None
        assert current_plan() is p1
        assert install_plan(None) is p1

    def test_maybe_fault_raises_typed(self):
        install_plan(FaultPlan().arm(SITE_DISPATCH, times=(0,)))
        from repro.runtime.chaos import maybe_fault

        with pytest.raises(InjectedFault) as ei:
            maybe_fault(SITE_DISPATCH)
        assert isinstance(ei.value, SystemError_)
        assert ei.value.site == SITE_DISPATCH

    def test_plan_from_spec(self):
        p = plan_from_spec("compile.build=0.2, page.alloc", seed=9)
        assert p.seed == 9
        assert p._sites[SITE_COMPILE_BUILD].spec.rate == 0.2
        assert p._sites[SITE_PAGE_ALLOC].spec.rate == 1.0
        p = plan_from_spec("all=0.05")
        assert set(p._sites) == set(ALL_SITES)
        with pytest.raises(ValueError, match="unknown fault site"):
            plan_from_spec("bogus=0.5")


# --------------------------------------------------------------------------
# disk-tier chaos: reads, writes and corruption heal, never crash
# --------------------------------------------------------------------------


class TestDiskChaos:
    def _compile_once(self, cache):
        from repro.core import ForgeCompiler, PipelineConfig

        comp = ForgeCompiler(PipelineConfig(backend="interpret"),
                             cache=cache)
        return comp.compile(lambda x: x * 2.0 + 1.0,
                            np.ones((4, 4), np.float32))

    def test_read_fault_is_a_miss_then_heals(self, tmp_path):
        from repro.core.cache import CompileCache, DiskCacheStore

        store = DiskCacheStore(str(tmp_path))
        self._compile_once(CompileCache(store=store))
        assert store.stats.writes == 1
        install_plan(FaultPlan().arm(SITE_DISK_READ, times=(0,)))
        s2 = DiskCacheStore(str(tmp_path))
        c2 = CompileCache(store=s2)
        m = self._compile_once(c2)  # read fails -> clean recompile
        assert s2.stats.misses == 1 and c2.stats.misses == 1
        assert s2.stats.writes == 1  # entry re-stored (healed)
        x = np.ones((4, 4), np.float32)
        np.testing.assert_array_equal(np.asarray(m(x)), x * 2.0 + 1.0)
        install_plan(None)
        s3 = DiskCacheStore(str(tmp_path))
        c3 = CompileCache(store=s3)
        self._compile_once(c3)
        assert c3.stats.disk_hits == 1  # the healed entry round-trips

    def test_corruption_detected_unlinked_and_healed(self, tmp_path):
        from repro.core.cache import CompileCache, DiskCacheStore

        store = DiskCacheStore(str(tmp_path))
        self._compile_once(CompileCache(store=store))
        install_plan(FaultPlan().arm(SITE_DISK_CORRUPT, times=(0,)))
        s2 = DiskCacheStore(str(tmp_path))
        c2 = CompileCache(store=s2)
        self._compile_once(c2)
        # checksum tripped: corrupt counted, file unlinked, recompiled
        # and re-stored — never a wrong program
        assert s2.stats.corrupt == 1 and c2.stats.misses == 1
        assert s2.stats.writes == 1
        assert len(s2) == 1

    def test_write_fault_degrades_to_memory_only(self, tmp_path):
        from repro.core.cache import CompileCache, DiskCacheStore

        install_plan(FaultPlan().arm(SITE_DISK_WRITE, times=(0,)))
        store = DiskCacheStore(str(tmp_path))
        cache = CompileCache(store=store)
        m = self._compile_once(cache)  # write fails; compile succeeds
        assert store.stats.write_errors == 1 and len(store) == 0
        x = np.ones((4, 4), np.float32)
        np.testing.assert_array_equal(np.asarray(m(x)), x * 2.0 + 1.0)
        # same memory cache still serves the program without disk
        m2 = self._compile_once(cache)
        assert cache.stats.hits == 1 and cache.stats.misses == 1
        np.testing.assert_array_equal(np.asarray(m2(x)),
                                      np.asarray(m(x)))


# --------------------------------------------------------------------------
# serving soak
# --------------------------------------------------------------------------

MAX_LEN, PS = 32, 8


def _workload(vocab, n=10):
    shared = _tokens(16, seed=20, vocab=vocab)  # 2 shared pages
    reqs = []
    for i in range(n):
        if i % 3 == 0:  # shared-prefix group -> prefix-tree traffic
            p = np.concatenate([shared, _tokens(4, seed=30 + i,
                                                vocab=vocab)])
        else:
            p = _tokens(3 + 2 * (i % 5), seed=40 + i, vocab=vocab)
        reqs.append(Request(rid=i, prompt=p, max_new=2 + (3 * i) % 5,
                            arrival=i // 3))
    return reqs


def _server(cfg, params, paged=False, **kw):
    return BatchedServer(cfg, params, max_len=MAX_LEN, mode="forge",
                         backend="interpret",
                         seq_bucket_policy="ladder:8,16,32",
                         paged=paged, kv_page_size=PS, **kw)


def _run(srv, reqs, plan=None, **kw):
    sched = SlotScheduler(srv, max_slots=4, **kw)
    sched.warmup(prompt_lens=[4, 8, 16, 24])
    prev = install_plan(plan)
    try:
        return sched.run(reqs)
    finally:
        install_plan(prev)


def _soak_plan(seed):
    return (FaultPlan(seed=seed)
            .arm(SITE_COMPILE_BUILD, rate=0.2)
            .arm(SITE_DISK_READ, rate=0.2)
            .arm(SITE_DISK_WRITE, rate=0.2)
            .arm(SITE_DISK_CORRUPT, rate=0.2)
            .arm(SITE_PAGE_ALLOC, rate=0.1)
            .arm(SITE_DISPATCH, rate=0.1, max_faults=4)
            .arm(SITE_LOGITS_NAN, times=(5,)))


class TestServeChaosSoak:
    def _check_soak(self, clean, out, reqs, plan):
        # 1. every request terminated with a typed outcome
        assert set(out["results"]) == {r.rid for r in reqs}
        for rid, r in out["results"].items():
            assert "tokens" in r
            if "error" in r:
                assert r["error_type"] in ("RequestError", "SystemError")
        # 2. unaffected requests are bitwise-equal to the fault-free run
        survivors = [rid for rid, r in out["results"].items()
                     if "error" not in r]
        for rid in survivors:
            np.testing.assert_array_equal(
                out["results"][rid]["tokens"],
                clean["results"][rid]["tokens"],
                err_msg=f"survivor rid {rid} diverged under faults",
            )
        # 3. the plan actually exercised the stack
        assert plan.faults_injected >= 1
        assert out["faults_injected"] == plan.faults_injected
        return survivors

    def test_contiguous_soak_survivors_bitwise(self, smoke_setup):
        cfg, _, params = smoke_setup
        reqs = _workload(cfg.vocab)
        clean = _run(_server(cfg, params), reqs)
        assert all("error" not in r for r in clean["results"].values())
        plan = _soak_plan(seed=11)
        out = _run(_server(cfg, params), reqs, plan=plan)
        survivors = self._check_soak(clean, out, reqs, plan)
        # the logits.nan injection quarantined exactly one row
        assert out["rows_quarantined"] == 1
        assert len(survivors) >= len(reqs) - 2

    def test_paged_soak_no_leaked_pages(self, smoke_setup):
        cfg, _, params = smoke_setup
        reqs = _workload(cfg.vocab)
        clean = _run(_server(cfg, params, paged=True), reqs)
        plan = _soak_plan(seed=7)
        srv = _server(cfg, params, paged=True)
        out = _run(srv, reqs, plan=plan)
        self._check_soak(clean, out, reqs, plan)
        # accounting invariants survive injected page exhaustion and
        # prefill failures: refcounts partition the pool, and nothing
        # beyond the trash pin + the prefix tree's chains stays live
        srv.page_pool.check()
        assert srv.page_pool.pages_in_use == \
            1 + srv.prefix_tree.cached_pages
        srv.prefix_tree.clear()
        srv.page_pool.check()
        assert srv.page_pool.pages_in_use == 1  # leaked pages == 0

    def test_same_plan_seed_reproduces_outcomes(self, smoke_setup):
        """Determinism: identical workload + identical plan seed =>
        identical outcomes, including which requests failed and every
        surviving token stream."""
        cfg, _, params = smoke_setup
        reqs = _workload(cfg.vocab, n=8)
        plan_a = (FaultPlan(seed=13)
                  .arm(SITE_DISPATCH, times=(2, 3, 4))
                  .arm(SITE_LOGITS_NAN, times=(1,)))
        plan_b = (FaultPlan(seed=13)
                  .arm(SITE_DISPATCH, times=(2, 3, 4))
                  .arm(SITE_LOGITS_NAN, times=(1,)))
        a = _run(_server(cfg, params), reqs, plan=plan_a)
        b = _run(_server(cfg, params), reqs, plan=plan_b)
        assert plan_a.log == plan_b.log
        assert set(a["results"]) == set(b["results"])
        for rid in a["results"]:
            ra, rb = a["results"][rid], b["results"][rid]
            np.testing.assert_array_equal(ra["tokens"], rb["tokens"])
            assert ra.get("error") == rb.get("error")

    def test_unrecoverable_faults_abort_with_typed_outcomes(
            self, smoke_setup):
        """Every dispatch failing forever exhausts containment: the run
        aborts — but returns, with a typed SystemError outcome per
        request and no exception escaping the loop."""
        cfg, _, params = smoke_setup
        reqs = _workload(cfg.vocab, n=4)
        plan = FaultPlan().arm(SITE_DISPATCH, rate=1.0)
        out = _run(_server(cfg, params), reqs, plan=plan,
                   max_consec_failures=3)
        assert out["aborted"] is True
        assert set(out["results"]) == {r.rid for r in reqs}
        for r in out["results"].values():
            assert r["error_type"] == "SystemError"
        assert out["tick_failures"] >= 3
        assert out["ticks_degraded"] >= 1  # cooldown engaged on the way

    def test_invalid_requests_isolated_from_batch(self, smoke_setup):
        cfg, _, params = smoke_setup
        good = Request(rid=0, prompt=_tokens(4, vocab=cfg.vocab),
                       max_new=3)
        bad_budget = Request(rid=1, prompt=_tokens(30, vocab=cfg.vocab),
                             max_new=8)  # 38 > max_len=32
        bad_prompt = Request(rid=2, prompt=None, max_new=2)
        bad_new = Request(rid=3, prompt=_tokens(4, vocab=cfg.vocab),
                          max_new=0)
        out = _run(_server(cfg, params),
                   [good, bad_budget, bad_prompt, bad_new])
        res = out["results"]
        assert len(res) == 4
        assert "error" not in res[0] and len(res[0]["tokens"]) == 3
        for rid in (1, 2, 3):
            assert res[rid]["error_type"] == "RequestError"
        assert out["requests_rejected"] == 3
