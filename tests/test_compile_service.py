"""Async background compilation + persistent on-disk compile cache
(ISSUE 7 acceptance criteria).

Covers the CompileService worker pool (dedup, priority, promotion,
failure retry), the BucketedModule async dispatch path (thundering
herd compiles once; warm-bucket fallback is bitwise-equal to the warm
program's own padded output; the exact program takes over once the
background build lands), the DiskCacheStore persistent tier
(roundtrip, checksum corruption detection, salt invalidation), the
eviction-coherence hook, and the serve-level restart-replay flow.
"""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompileCache,
    CompileService,
    DiskCacheStore,
    ForgeCompiler,
    PipelineConfig,
    forge_compile_bucketed,
    get_compile_cache,
)


@pytest.fixture(autouse=True)
def _isolate_global_cache():
    """Serve's --cache-dir attaches a disk store to the process-global
    cache; snapshot/restore it so this module never leaks tmp-dir
    stores (or entries) into the rest of the suite."""
    g = get_compile_cache()
    store0 = g.store
    yield
    g.store = store0


def _fn(x):
    return jnp.cumsum(x, axis=-1) * 2.0 + 1.0


def _x(b, seed=0):
    return np.random.default_rng(seed).normal(size=(b, 4)).astype(np.float32)


# --------------------------------------------------------------------------
# CompileService unit behavior (no compiler involved)
# --------------------------------------------------------------------------


class TestCompileService:
    def test_dedup_builds_once(self):
        svc = CompileService(workers=2)
        built = []
        gate = threading.Event()

        def build():
            gate.wait(5.0)
            built.append(1)
            return "value"

        futs = [svc.submit("k", build) for _ in range(8)]
        gate.set()
        assert all(f.result(10.0) == "value" for f in futs)
        assert len(built) == 1
        assert svc.stats.submitted == 1
        assert svc.stats.dedup_hits == 7
        svc.shutdown()

    def test_foreground_preempts_speculative(self):
        svc = CompileService(workers=1)
        order = []
        gate = threading.Event()
        svc.submit("blocker", lambda: gate.wait(5.0))
        time.sleep(0.05)  # let the worker claim the blocker
        svc.submit("spec-a", lambda: order.append("spec-a"),
                   foreground=False)
        svc.submit("spec-b", lambda: order.append("spec-b"),
                   foreground=False)
        fg = svc.submit("fg", lambda: order.append("fg"))
        gate.set()
        fg.result(10.0)
        svc.wait_idle(10.0)
        assert order[0] == "fg"  # jumped the speculative queue
        svc.shutdown()

    def test_promotion_shares_future(self):
        svc = CompileService(workers=1)
        gate = threading.Event()
        svc.submit("blocker", lambda: gate.wait(5.0))
        time.sleep(0.05)
        spec = svc.submit("k", lambda: 42, foreground=False)
        fg = svc.submit("k", lambda: 43)  # promote, not a second build
        assert fg is spec
        gate.set()
        assert fg.result(10.0) == 42
        assert svc.stats.promoted == 1
        assert svc.stats.submitted == 2  # blocker + k
        svc.shutdown()

    def test_failed_build_allows_retry(self):
        # legacy semantics: no retry, no quarantine — the key is simply
        # forgotten on failure so a resubmit builds again
        svc = CompileService(workers=1, max_retries=0,
                             poison_failures=False)

        def boom():
            raise RuntimeError("transient")

        with pytest.raises(RuntimeError):
            svc.submit("k", boom).result(10.0)
        assert svc.submit("k", lambda: "ok").result(10.0) == "ok"
        assert svc.stats.failed == 1
        assert svc.stats.retries == 0
        assert svc.stats.completed >= 1
        svc.shutdown()

    def test_transient_failure_retried_with_backoff(self):
        svc = CompileService(workers=1, max_retries=2,
                             retry_backoff_s=0.005)
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise RuntimeError("transient")
            return "recovered"

        assert svc.submit("k", flaky).result(10.0) == "recovered"
        assert len(calls) == 3
        assert svc.stats.retries == 2
        assert svc.stats.failed == 0
        assert svc.stats.completed == 1
        svc.shutdown()

    def test_deterministic_failure_poisons_key(self):
        svc = CompileService(workers=1, max_retries=1,
                             retry_backoff_s=0.002)
        calls = []

        def boom():
            calls.append(1)
            raise RuntimeError("deterministic")

        with pytest.raises(RuntimeError, match="deterministic"):
            svc.submit("k", boom).result(10.0)
        assert len(calls) == 2  # first attempt + 1 retry
        assert svc.stats.failed == 1
        assert svc.poisoned_keys() == ["k"]
        # resubmits fail fast from the quarantine — no rebuild hot-loop
        with pytest.raises(RuntimeError, match="deterministic"):
            svc.submit("k", boom).result(10.0)
        assert len(calls) == 2
        assert svc.stats.poison_hits == 1
        # clearing the quarantine lets a fixed build through
        assert svc.clear_poisoned("k") == 1
        assert svc.submit("k", lambda: "fixed").result(10.0) == "fixed"
        svc.shutdown()

    def test_dead_worker_respawned_and_job_rescued(self):
        from repro.runtime import chaos

        svc = CompileService(workers=1, max_retries=0)
        prev = chaos.install_plan(
            chaos.FaultPlan(seed=3).arm(chaos.SITE_COMPILE_WORKER,
                                        times=(0,))
        )
        try:
            # the worker thread dies AFTER claiming this job; without
            # the reaper the future would be stranded forever
            fut = svc.submit("k", lambda: "survived")
            assert svc.result(fut, timeout=10.0) == "survived"
            assert svc.stats.worker_restarts >= 1
            assert svc.stats.requeued == 1
        finally:
            chaos.install_plan(prev)
            svc.shutdown()

    def test_hung_build_abandoned(self):
        svc = CompileService(workers=1, max_retries=0,
                             hang_timeout_s=0.05)
        gate = threading.Event()
        fut = svc.submit("hung", lambda: gate.wait(10.0))
        from repro.runtime.chaos import SystemError_
        with pytest.raises(SystemError_, match="hang timeout"):
            svc.result(fut, timeout=10.0)
        assert svc.stats.hangs_abandoned == 1
        assert svc.stats.worker_restarts >= 1
        # the replacement worker keeps serving new jobs
        assert svc.submit("next", lambda: "ok").result(10.0) == "ok"
        gate.set()
        svc.shutdown()

    def test_shutdown_cancels_queued(self):
        svc = CompileService(workers=1)
        gate = threading.Event()
        svc.submit("blocker", lambda: gate.wait(5.0))
        time.sleep(0.05)
        queued = svc.submit("never", lambda: 1)
        gate.set()
        svc.shutdown(wait=True)
        assert queued.cancelled() or queued.done()


# --------------------------------------------------------------------------
# BucketedModule async dispatch
# --------------------------------------------------------------------------


class TestAsyncDispatch:
    def test_thundering_herd_compiles_once(self):
        """Eight threads hitting the same cold bucket (nothing warm to
        fall back to) all block on ONE build — compiles == 1."""
        svc = CompileService(workers=2)
        mod = forge_compile_bucketed(
            _fn, in_axes=0, policy="pow2",
            async_compile=True, service=svc,
        )
        x = _x(5)
        outs, errs = [None] * 8, []

        def call(i):
            try:
                outs[i] = np.asarray(mod(x))
            except Exception as e:  # pragma: no cover
                errs.append(e)

        threads = [threading.Thread(target=call, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30.0)
        assert not errs
        assert mod.stats.compiles == 1
        assert svc.stats.submitted == 1
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)
        svc.shutdown()

    def test_fallback_bitwise_then_exact_switch(self):
        """Acceptance: a cold-bucket dispatch with a warm dominating
        bucket never blocks — it pads up and is bitwise-equal to the
        warm program's own output on the same padded inputs; once the
        background build lands, the exact program takes over and is
        token-exact vs a cold blocking (sync) run."""
        svc = CompileService(workers=1)
        # example args warm the B8 bucket eagerly (sync, like warmup)
        mod = forge_compile_bucketed(
            _fn, np.ones((8, 4), np.float32), in_axes=0, policy="pow2",
            async_compile=True, service=svc,
        )
        assert mod.has_program(mod.key_for_extents(8))
        wait0 = mod.stats.compile_wait_s  # eager warmup stall (sync)
        x = _x(3)
        y_fb = np.asarray(mod(x))  # exact B4 is cold -> warm B8 fallback
        assert mod.stats.fallback_calls == 1
        assert mod.stats.fallback_cells_padded == 8 - 4
        assert mod.stats.compile_wait_s == wait0  # never blocked
        # bitwise vs the warm program's solo output on the padded batch
        xp = np.pad(x, ((0, 5), (0, 0)), mode="edge")
        y_warm = np.asarray(mod(xp))
        np.testing.assert_array_equal(y_fb, y_warm[:3])
        # the background build lands -> the exact bucket takes over
        assert svc.wait_idle(30.0)
        assert mod.has_program(mod.key_for_extents(4))
        y_exact = np.asarray(mod(x))
        assert mod.stats.fallback_calls == 1  # no new fallback
        assert mod.stats.compile_background_s > 0.0
        # token-exact vs a cold sync module that blocked on B4
        sync = forge_compile_bucketed(_fn, in_axes=0, policy="pow2")
        np.testing.assert_array_equal(y_exact, np.asarray(sync(x)))
        svc.shutdown()

    def test_first_dispatch_blocks_without_warm(self):
        """With nothing warm the very first dispatch must block (and
        the stall is accounted as request-visible wait)."""
        svc = CompileService(workers=1)
        mod = forge_compile_bucketed(
            _fn, in_axes=0, policy="pow2",
            async_compile=True, service=svc,
        )
        y = np.asarray(mod(_x(3)))
        assert mod.stats.compiles == 1
        assert mod.stats.compile_wait_s > 0.0
        assert mod.stats.fallback_calls == 0
        sync = forge_compile_bucketed(_fn, in_axes=0, policy="pow2")
        np.testing.assert_array_equal(y, np.asarray(sync(_x(3))))
        svc.shutdown()


# --------------------------------------------------------------------------
# persistent disk tier
# --------------------------------------------------------------------------


def _compile_once(cache, backend="segment_jit"):
    comp = ForgeCompiler(PipelineConfig(backend=backend), cache=cache)
    return comp.compile(_fn, np.ones((4, 4), np.float32))


class TestDiskCache:
    def test_restart_replays_with_zero_builds(self, tmp_path):
        store = DiskCacheStore(str(tmp_path))
        c1 = CompileCache(store=store)
        m1 = _compile_once(c1)
        assert c1.stats.misses == 1
        assert store.stats.writes == 1
        assert len(store) == 1
        # simulated restart: fresh memory cache over the same directory
        c2 = CompileCache(store=DiskCacheStore(str(tmp_path)))
        m2 = _compile_once(c2)
        assert c2.stats.misses == 0
        assert c2.stats.disk_hits == 1
        assert m2.result.cache_disk_hit
        x = _x(4)
        np.testing.assert_array_equal(np.asarray(m1(x)), np.asarray(m2(x)))

    def test_interpret_backend_roundtrip(self, tmp_path):
        c1 = CompileCache(store=DiskCacheStore(str(tmp_path)))
        m1 = _compile_once(c1, backend="interpret")
        c2 = CompileCache(store=DiskCacheStore(str(tmp_path)))
        m2 = _compile_once(c2, backend="interpret")
        assert c2.stats.disk_hits == 1 and c2.stats.misses == 0
        x = _x(4)
        np.testing.assert_array_equal(np.asarray(m1(x)), np.asarray(m2(x)))

    def _entry_files(self, root):
        return [os.path.join(r, f) for r, _d, fs in os.walk(root)
                for f in fs if f.endswith(".forgec")]

    def test_corrupt_entry_detected_and_recompiled(self, tmp_path):
        c1 = CompileCache(store=DiskCacheStore(str(tmp_path)))
        _compile_once(c1)
        files = self._entry_files(tmp_path)
        assert files
        for p in files:  # truncate: checksum must catch it
            blob = open(p, "rb").read()
            open(p, "wb").write(blob[: len(blob) // 2])
        store2 = DiskCacheStore(str(tmp_path))
        c2 = CompileCache(store=store2)
        m2 = _compile_once(c2)
        assert store2.stats.corrupt == 1
        assert c2.stats.misses == 1  # recompiled, not crashed
        assert store2.stats.writes == 1  # entry healed on disk
        x = _x(4)
        sync = _compile_once(CompileCache())
        np.testing.assert_array_equal(np.asarray(m2(x)),
                                      np.asarray(sync(x)))

    def test_garbage_entry_detected(self, tmp_path):
        c1 = CompileCache(store=DiskCacheStore(str(tmp_path)))
        _compile_once(c1)
        for p in self._entry_files(tmp_path):
            open(p, "wb").write(os.urandom(256))
        store2 = DiskCacheStore(str(tmp_path))
        c2 = CompileCache(store=store2)
        _compile_once(c2)
        assert store2.stats.corrupt == 1
        assert c2.stats.misses == 1
        # the corrupt file was unlinked and rewritten
        assert len(store2) == 1

    def test_salt_invalidates_by_address(self, tmp_path):
        a = DiskCacheStore(str(tmp_path), salt="jax=1")
        assert a.store_entry("k", {"v": 1})
        b = DiskCacheStore(str(tmp_path), salt="jax=2")
        assert b.load_entry("k") is None  # different address, clean miss
        assert b.stats.misses == 1
        assert a.load_entry("k") == {"v": 1}

    def test_foreign_file_key_mismatch(self, tmp_path):
        """A store re-rooted onto foreign files (or a path collision)
        must miss, not deserialize the wrong program."""
        s = DiskCacheStore(str(tmp_path))
        s.store_entry("k1", {"v": 1})
        import shutil

        p2 = s.path_for("k2")
        os.makedirs(os.path.dirname(p2), exist_ok=True)
        shutil.copy(s.path_for("k1"), p2)
        assert s.load_entry("k2") is None
        assert s.stats.corrupt == 1
        assert not os.path.exists(p2)  # poisoned file unlinked


# --------------------------------------------------------------------------
# eviction coherence
# --------------------------------------------------------------------------


class TestEvictionCoherence:
    def test_evict_cold_drops_cache_entry(self, tmp_path):
        store = DiskCacheStore(str(tmp_path))
        cache = CompileCache(store=store)
        comp = ForgeCompiler(PipelineConfig(backend="segment_jit"),
                             cache=cache)
        mod = comp.compile_bucketed(_fn, in_axes=0, policy="pow2")
        for b in (2, 4, 8):
            mod(_x(b))
        assert len(cache) == 3
        n_disk = len(store)
        victims = mod.evict_cold(1)
        assert len(victims) == 2
        # coherence: the memory tier dropped the retired programs...
        assert cache.stats.coherence_drops == 2
        assert len(cache) == 1
        # ...but the disk tier keeps them (it IS the cold tier)
        assert len(store) == n_disk
        # a re-dispatch of an evicted bucket replays from disk
        y = np.asarray(mod(_x(2)))
        assert cache.stats.disk_hits == 1
        sync = forge_compile_bucketed(_fn, in_axes=0, policy="pow2")
        np.testing.assert_array_equal(y, np.asarray(sync(_x(2))))

    def test_evict_without_store_only_counts(self):
        cache = CompileCache()
        comp = ForgeCompiler(PipelineConfig(backend="segment_jit"),
                             cache=cache)
        mod = comp.compile_bucketed(_fn, in_axes=0, policy="pow2")
        mod(_x(2))
        mod(_x(4))
        mod.evict_cold(1)
        assert cache.stats.coherence_drops == 1
        assert len(cache) == 1


# --------------------------------------------------------------------------
# serve-level acceptance
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_setup():
    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("forge-125m", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestServeAsync:
    def test_warm_fallback_never_blocks_and_switches(self, smoke_setup):
        """Acceptance: with --async-compile a dispatch discovering a
        cold bucket never blocks when a dominating warm bucket exists;
        the fallback generation is token-exact vs the warm-padded sync
        server, and the post-switch generation is token-exact vs a
        cold blocking run."""
        from repro.launch.serve import BatchedServer

        cfg, params = smoke_setup
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab, (3, 8)).astype(np.int32)
        srv = BatchedServer(cfg, params, max_len=64, mode="forge",
                            async_compile=True)
        try:
            srv.warmup([8], prompt_lens=[8])  # ONLY the B8 rung is warm
            bs = srv.bucketed.stats
            r1 = srv.generate(prompts, 4)  # exact rung B4 is cold
            assert bs.fallback_calls >= 1
            assert bs.compile_wait_s == 0.0  # the tick never stalled
            sync = BatchedServer(cfg, params, max_len=64, mode="forge")
            sync.warmup([8], prompt_lens=[8])
            np.testing.assert_array_equal(
                r1["tokens"], sync.generate(prompts, 4)["tokens"]
            )
            # background build lands -> exact bucket takes over
            assert srv.compile_service.wait_idle(60.0)
            assert srv.bucketed.has_program(
                srv.bucketed.key_for_extents(4)
            )
            r2 = srv.generate(prompts, 4)
            cold = BatchedServer(cfg, params, max_len=64, mode="forge")
            np.testing.assert_array_equal(
                r2["tokens"], cold.generate(prompts, 4)["tokens"]
            )
        finally:
            srv.compile_service.shutdown()

    def test_scheduler_async_token_parity(self, smoke_setup):
        """SlotScheduler without warmup: cold rungs discovered mid-
        serve fall back to warm rungs (warm_fallbacks > 0) and the
        emitted tokens match the sync scheduler exactly."""
        from repro.launch.serve import BatchedServer, Request, SlotScheduler

        cfg, params = smoke_setup

        def reqs():
            rng = np.random.default_rng(1)
            return [
                Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, (6,)).astype(
                            np.int32),
                        max_new=4, arrival=i // 4)
                for i in range(10)
            ]

        srv = BatchedServer(cfg, params, max_len=64, mode="forge",
                            async_compile=True)
        try:
            sched = SlotScheduler(srv, max_slots=8)
            res = sched.run(reqs())
            assert res["warm_fallbacks"] > 0
            srv2 = BatchedServer(cfg, params, max_len=64, mode="forge")
            res2 = SlotScheduler(srv2, max_slots=8).run(reqs())
            a = {r: v["tokens"].tolist() for r, v in res["results"].items()}
            b = {r: v["tokens"].tolist() for r, v in res2["results"].items()}
            assert a == b
        finally:
            srv.compile_service.shutdown()

    def test_restart_replay_zero_builds(self, smoke_setup, tmp_path):
        """Acceptance: a server restart against a populated --cache-dir
        replays the warmed ladder from disk with zero full builds."""
        from repro.launch.serve import BatchedServer

        cfg, params = smoke_setup
        import repro.models._forge as forge_glue

        g = get_compile_cache()
        # earlier tests memoized the inner per-block bodies; reset so
        # run 1 actually compiles (and persists) the whole ladder
        forge_glue.clear_cache()
        g.clear()
        srv1 = BatchedServer(cfg, params, max_len=64, mode="forge",
                             cache_dir=str(tmp_path))
        srv1.warmup([2], prompt_lens=[8])
        assert srv1.compile_cache.stats.misses > 0
        assert srv1.compile_cache.store.stats.writes > 0
        # simulated restart: fresh per-server cache, fresh global-cache
        # state, fresh per-block body memo — only the directory survives
        forge_glue.clear_cache()
        g.clear()
        g.store = None
        srv2 = BatchedServer(cfg, params, max_len=64, mode="forge",
                             cache_dir=str(tmp_path))
        srv2.warmup([2], prompt_lens=[8])
        assert srv2.compile_cache.stats.misses == 0
        assert srv2.compile_cache.stats.disk_hits > 0
        assert g.stats.misses == 0  # inner forge bodies replayed too
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
        t1 = srv1.generate(prompts, 4)["tokens"]
        t2 = srv2.generate(prompts, 4)["tokens"]
        np.testing.assert_array_equal(t1, t2)
