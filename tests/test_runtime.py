"""Tests for the training-side runtime: Supervisor checkpoint/restart
and the StragglerMonitor (the serving-side chaos harness is covered by
tests/test_chaos.py)."""
import numpy as np
import pytest

from repro.runtime import (
    SimulatedFault,
    StragglerMonitor,
    Supervisor,
)


def _counting_harness(checkpoint_every=2):
    """A tiny deterministic 'training' loop: state is the running sum of
    step indices, so any replay divergence is visible in the final sum."""
    saved = {"step": 0, "state": 0}

    def step_fn(state, batch):
        return state + batch, {"loss": float(batch)}

    def data_fn(step):
        return step  # deterministic stream: batch IS the step index

    def save_fn(step, state):
        saved["step"], saved["state"] = step, state

    def restore_fn():
        return saved["state"], saved["step"]

    return saved, dict(step_fn=step_fn, data_fn=data_fn, save_fn=save_fn,
                       restore_fn=restore_fn,
                       checkpoint_every=checkpoint_every)


class TestSupervisor:
    def test_clean_run(self):
        _, kw = _counting_harness()
        sup = Supervisor(**kw)
        state, rep = sup.run(0, 0, 10)
        assert state == sum(range(10))
        assert rep.steps_run == 10
        assert rep.failures == 0 and rep.restores == 0
        assert [h["step"] for h in rep.history] == list(range(10))

    def test_transient_fault_restores_and_replays(self):
        saved, kw = _counting_harness(checkpoint_every=2)
        fired = []

        def hook(step):
            if step == 5 and not fired:
                fired.append(step)
                raise SimulatedFault("node lost")

        sup = Supervisor(**kw, fault_hook=hook)
        state, rep = sup.run(0, 0, 10)
        # replay from the restored checkpoint is bit-identical: the
        # final state matches the fault-free run exactly
        assert state == sum(range(10))
        assert rep.failures == 1 and rep.restores == 1
        # step 4 replayed after restoring the step-4 checkpoint; the
        # faulted attempt at step 5 never ran, so 5 appears once
        assert rep.steps_run == 11
        replayed = [h["step"] for h in rep.history]
        assert replayed.count(4) == 2 and replayed.count(5) == 1

    def test_repeated_fault_escalates(self):
        _, kw = _counting_harness()

        def hook(step):
            if step == 3:
                raise SimulatedFault("persistent fault")

        sup = Supervisor(**kw, max_retries=2, fault_hook=hook)
        with pytest.raises(RuntimeError, match="escalating"):
            sup.run(0, 0, 10)

    def test_retry_budget_is_per_step(self):
        # one fault at each of two different steps: neither step exceeds
        # its own retry budget, so the run completes
        saved, kw = _counting_harness(checkpoint_every=1)
        seen = set()

        def hook(step):
            if step in (2, 6) and step not in seen:
                seen.add(step)
                raise SimulatedFault(f"blip at {step}")

        sup = Supervisor(**kw, max_retries=1, fault_hook=hook)
        state, rep = sup.run(0, 0, 8)
        assert state == sum(range(8))
        assert rep.failures == 2 and rep.restores == 2


class TestStragglerMonitor:
    def test_no_flag_before_min_samples(self):
        mon = StragglerMonitor(n_hosts=4, min_samples=5)
        for _ in range(4):
            mon.observe([1.0, 1.0, 1.0, 3.0])
        assert mon.stragglers() == []

    def test_flags_slow_host(self):
        mon = StragglerMonitor(n_hosts=4, min_samples=5, threshold=1.5)
        for _ in range(10):
            mon.observe([1.0, 1.0, 1.0, 2.0])
        assert mon.stragglers() == [3]

    def test_ewma_recovers_after_transient(self):
        # a brief slowdown decays out of the EWMA: no flag once the host
        # is back to fleet pace long enough
        mon = StragglerMonitor(n_hosts=2, alpha=0.5, min_samples=2,
                               threshold=1.5)
        mon.observe([1.0, 5.0])
        for _ in range(12):
            mon.observe([1.0, 1.0])
        assert mon.stragglers() == []

    def test_observe_accepts_dict(self):
        mon = StragglerMonitor(n_hosts=3, min_samples=1)
        mon.observe({0: 1.0, 1: 1.0, 2: 4.0})
        assert mon.work_ratios().shape == (3,)

    def test_rebalanced_batches_sum_and_favor_fast_hosts(self):
        mon = StragglerMonitor(n_hosts=4, min_samples=1)
        for _ in range(6):
            mon.observe([1.0, 1.0, 1.0, 2.0])
        sizes = mon.rebalanced_host_batches(64)
        assert sum(sizes) == 64
        assert min(sizes[:3]) > sizes[3]  # straggler gets less work

    def test_uniform_hosts_get_uniform_batches(self):
        mon = StragglerMonitor(n_hosts=4, min_samples=1)
        mon.observe([1.0, 1.0, 1.0, 1.0])
        assert mon.rebalanced_host_batches(32) == [8, 8, 8, 8]
        np.testing.assert_allclose(mon.work_ratios(), np.ones(4))
