"""Phase-2 pass tests: each pass + the fixpoint pipeline + fusion variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core.capture import graph_to_fn, trace_to_graph
from repro.core.passes import (
    AttentionFusionPass,
    CSEPass,
    ConstantFoldingPass,
    DCEPass,
    DeviceConstantPass,
    LayoutOptimizationPass,
    OperatorFusionPass,
    PipelineConfig,
    run_forge_passes,
)


def capture(fn, *args):
    return trace_to_graph(fn, *args).graph


def assert_equiv(g, fn, args, rtol=1e-5, atol=1e-5):
    out = graph_to_fn(g)(*args)
    expect = fn(*args)
    if not isinstance(expect, (tuple, list)):
        expect = [expect]
    for o, e in zip(out, expect):
        np.testing.assert_allclose(
            np.asarray(o, dtype=np.float32),
            np.asarray(e, dtype=np.float32),
            rtol=rtol, atol=atol,
        )


class TestDCE:
    def test_erases_dead_chain(self):
        def f(x):
            dead = jnp.sum(x * 3.0)  # noqa: F841 — dead subexpression
            return x + 1.0

        g = capture(f, np.ones((4,), np.float32))
        n0 = g.num_nodes()
        DCEPass().run(g)
        assert g.num_nodes() < n0
        g.validate()
        assert_equiv(g, f, [np.ones((4,), np.float32)])

    def test_noop_on_live_graph(self):
        def f(x):
            return x * 2.0 + 1.0

        g = capture(f, np.ones((4,), np.float32))
        assert DCEPass().run(g) is False


class TestCSE:
    def test_merges_duplicates(self):
        def f(x):
            a = jnp.tanh(x)
            b = jnp.tanh(x)
            return a + b

        g = capture(f, np.ones((4,), np.float32))
        n0 = g.num_nodes()
        assert CSEPass().run(g)
        assert g.num_nodes() == n0 - 1
        assert_equiv(g, f, [np.ones((4,), np.float32)])

    def test_respects_params(self):
        def f(x):
            return jnp.sum(x, axis=0) + jnp.sum(x, axis=1)

        g = capture(f, np.ones((4, 4), np.float32))
        n0 = g.num_nodes()
        CSEPass().run(g)
        assert g.num_nodes() == n0  # different axes: not CSE-able


class TestConstantFolding:
    def test_folds_const_subgraph(self):
        def f(x):
            table = jnp.arange(8.0) * 2.0 + 1.0  # pure-constant chain
            return x * table

        g = capture(f, np.ones((8,), np.float32))
        ConstantFoldingPass().run(g)
        DCEPass().run(g)
        ops = [n.op for n in g.nodes.values()]
        assert ops.count("mul") == 1  # only the data-dependent mul survives
        assert_equiv(g, f, [np.ones((8,), np.float32)])

    def test_identity_arith(self):
        def f(x):
            return (x + 0.0) * 1.0

        g = capture(f, np.ones((4,), np.float32))
        ConstantFoldingPass().run(g)
        assert g.num_nodes() == 0  # both identities collapse
        assert_equiv(g, f, [np.ones((4,), np.float32)])

    def test_size_cap(self):
        def f(x):
            big = jnp.ones((2048, 2048)) * 2.0  # 4M elements > cap
            return x + big[0, 0]

        g = capture(f, np.float32(1.0))
        p = ConstantFoldingPass(max_elements=1 << 20)
        p.run(g)
        # the 4M-element broadcast must not be materialized
        assert all(np.prod(np.shape(c)) <= 1 << 20 for c in g.consts)


def _sdpa_fn(causal=True, gqa=False, scale=True, extra_mask=False):
    def f(q, k, v, *rest):
        B, H, S, D = q.shape
        if gqa:
            KVH = k.shape[1]
            grp = H // KVH
            k2 = jnp.broadcast_to(k[:, :, None], (B, KVH, grp, S, D)).reshape(B, H, S, D)
            v2 = jnp.broadcast_to(v[:, :, None], (B, KVH, grp, S, D)).reshape(B, H, S, D)
        else:
            k2, v2 = k, v
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k2, preferred_element_type=jnp.float32)
        if scale:
            s = s * (1.0 / np.sqrt(D))
        if causal:
            row = lax.broadcasted_iota(jnp.int32, (S, S), 0)
            col = lax.broadcasted_iota(jnp.int32, (S, S), 1)
            s = jnp.where(row >= col, s, jnp.asarray(jnp.finfo(s.dtype).min, s.dtype))
        if extra_mask:
            s = s + rest[0]
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v2.dtype), v2)

    return f


def _sdpa_args(rng, B=1, H=4, KVH=4, S=8, D=4, extra_mask=False):
    args = [
        rng.standard_normal((B, H, S, D)).astype(np.float32) * 0.5,
        rng.standard_normal((B, KVH, S, D)).astype(np.float32) * 0.5,
        rng.standard_normal((B, KVH, S, D)).astype(np.float32) * 0.5,
    ]
    if extra_mask:
        args.append(rng.standard_normal((B, H, S, S)).astype(np.float32))
    return args


class TestAttentionFusion:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("gqa", [True, False])
    def test_variants(self, rng, causal, gqa):
        f = _sdpa_fn(causal=causal, gqa=gqa)
        args = _sdpa_args(rng, KVH=2 if gqa else 4)
        g = capture(f, *args)
        ConstantFoldingPass().run(g)
        p = AttentionFusionPass()
        assert p.run(g), f"no fusion for causal={causal} gqa={gqa}"
        node = next(n for n in g.nodes.values() if n.op == "forge.sdpa")
        assert node.params["causal"] == causal
        assert node.params["groups"] == (2 if gqa else 1)
        g.validate()
        assert_equiv(g, f, args)

    def test_additive_mask_kept_as_operand(self, rng):
        f = _sdpa_fn(causal=False, extra_mask=True)
        args = _sdpa_args(rng, extra_mask=True)
        g = capture(f, *args)
        p = AttentionFusionPass()
        assert p.run(g)
        node = next(n for n in g.nodes.values() if n.op == "forge.sdpa")
        assert node.params["has_mask"] and node.params["mask_mode"] == "add"
        assert len(node.invars) == 4
        assert_equiv(g, f, args)

    def test_no_scale_uses_identity(self, rng):
        f = _sdpa_fn(causal=False, scale=False)
        args = _sdpa_args(rng)
        g = capture(f, *args)
        assert AttentionFusionPass().run(g)
        node = next(n for n in g.nodes.values() if n.op == "forge.sdpa")
        assert node.params["scale"] == 1.0
        assert_equiv(g, f, args)

    def test_alpha_zero_disables(self, rng):
        f = _sdpa_fn()
        args = _sdpa_args(rng)
        g = capture(f, *args)
        p = AttentionFusionPass(alpha=0.0)
        assert p.run(g) is False
        assert p.last_detail["matched"] == 1

    def test_shared_scores_not_fused(self, rng):
        """If the softmax output feeds a second consumer, fusion must bail."""

        def f(q, k, v):
            s = jnp.einsum("bhqd,bhkd->bhqk", q, k)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhqk,bhkd->bhqd", p, v)
            return o, p  # p escapes!

        args = _sdpa_args(rng)
        g = capture(f, *args)
        assert AttentionFusionPass().run(g) is False


class TestOperatorFusion:
    @pytest.mark.parametrize("act", ["relu", "silu", "gelu", "tanh"])
    @pytest.mark.parametrize("bias", [True, False])
    def test_linear_act(self, rng, act, bias):
        actf = {"relu": jax.nn.relu, "silu": jax.nn.silu,
                "gelu": jax.nn.gelu, "tanh": jnp.tanh}[act]

        def f(x, w, b):
            h = x @ w
            if bias:
                h = h + b
            return actf(h)

        x = rng.standard_normal((4, 8)).astype(np.float32) * 0.5
        w = rng.standard_normal((8, 16)).astype(np.float32) * 0.5
        b = rng.standard_normal((16,)).astype(np.float32) * 0.5
        g = capture(f, x, w, b)
        p = OperatorFusionPass()
        assert p.run(g)
        node = next(n for n in g.nodes.values() if n.op == "forge.linear_act")
        assert node.params["act"] == act
        assert node.params["has_bias"] == bias
        assert_equiv(g, f, [x, w, b], rtol=1e-4, atol=1e-5)

    def test_gelu_exact(self, rng):
        def f(x, w):
            return jax.nn.gelu(x @ w, approximate=False)

        x = rng.standard_normal((4, 8)).astype(np.float32) * 0.5
        w = rng.standard_normal((8, 8)).astype(np.float32) * 0.5
        g = capture(f, x, w)
        assert OperatorFusionPass().run(g)
        node = next(n for n in g.nodes.values() if n.op == "forge.linear_act")
        assert node.params["act"] == "gelu_exact"
        assert_equiv(g, f, [x, w], rtol=1e-4, atol=1e-5)

    def test_swiglu(self, rng):
        def f(x, wg, wu):
            return jax.nn.silu(x @ wg) * (x @ wu)

        x = rng.standard_normal((4, 8)).astype(np.float32) * 0.5
        wg = rng.standard_normal((8, 16)).astype(np.float32) * 0.5
        wu = rng.standard_normal((8, 16)).astype(np.float32) * 0.5
        g = capture(f, x, wg, wu)
        p = OperatorFusionPass()
        assert p.run(g)
        assert any(n.op == "forge.swiglu" for n in g.nodes.values())
        assert_equiv(g, f, [x, wg, wu], rtol=1e-4, atol=1e-5)

    def test_mm_residual(self, rng):
        def f(x, w, r):
            return x @ w + r

        x = rng.standard_normal((4, 8)).astype(np.float32)
        w = rng.standard_normal((8, 8)).astype(np.float32)
        r = rng.standard_normal((4, 8)).astype(np.float32)
        g = capture(f, x, w, r)
        assert OperatorFusionPass().run(g)
        node = next(n for n in g.nodes.values() if n.op == "forge.linear_act")
        assert node.params["has_residual"]
        assert_equiv(g, f, [x, w, r], rtol=1e-5, atol=1e-5)


class TestLayout:
    def test_transpose_cancel(self, rng):
        def f(x):
            return jnp.transpose(jnp.transpose(x, (1, 0)), (1, 0)) + 1.0

        x = rng.standard_normal((3, 5)).astype(np.float32)
        g = capture(f, x)
        assert LayoutOptimizationPass().run(g)
        assert not any(n.op == "transpose" for n in g.nodes.values())
        assert_equiv(g, f, [x])

    def test_noop_convert_erased(self, rng):
        def f(x):
            return x.astype(jnp.float32) + 1.0  # already f32

        x = rng.standard_normal((4,)).astype(np.float32)
        g = capture(f, x)
        LayoutOptimizationPass().run(g)
        assert not any(n.op == "convert_element_type" for n in g.nodes.values())

    def test_dot_transpose_absorbed(self, rng):
        def f(x, w):
            return x @ w.T

        x = rng.standard_normal((4, 8)).astype(np.float32)
        w = rng.standard_normal((16, 8)).astype(np.float32)
        g = capture(f, x, w)
        assert LayoutOptimizationPass().run(g)
        assert not any(n.op == "transpose" for n in g.nodes.values())
        assert_equiv(g, f, [x, w], rtol=1e-5)


class TestDeviceConstant:
    def test_promotes_array_literals(self):
        def f(x):
            return x + jnp.asarray([1.0, 2.0, 3.0, 4.0])

        g = capture(f, np.ones((4,), np.float32))
        n_consts0 = len(g.consts)
        p = DeviceConstantPass()
        changed = p.run(g)
        if changed:
            assert len(g.consts) > n_consts0
        # idempotent
        assert p.run(g) is False


class TestPipeline:
    def test_fixpoint_converges(self, block_fn, block_args):
        g = capture(block_fn, *block_args)
        recs = run_forge_passes(g, cfg=PipelineConfig(max_rounds=3))
        rounds = {r.round for r in recs}
        # second round must be a no-op (fixpoint) -> at most 2 rounds run
        last_round = max(rounds)
        assert not any(r.modified for r in recs if r.round == last_round)

    def test_node_reduction_band(self, block_fn, block_args):
        g = capture(block_fn, *block_args)
        n0 = g.num_nodes()
        run_forge_passes(g)
        assert g.num_nodes() < n0 * 0.9  # at least 10% reduction

    def test_semantics_preserved(self, block_fn, block_args):
        g = capture(block_fn, *block_args)
        run_forge_passes(g)
        assert_equiv(g, block_fn, block_args, rtol=1e-4, atol=1e-4)

    def test_ablation_hooks(self, block_fn, block_args):
        g = capture(block_fn, *block_args)
        cfg = PipelineConfig(enable={"attention_fusion": False})
        run_forge_passes(g, cfg=cfg)
        assert not any(n.op == "forge.sdpa" for n in g.nodes.values())
        assert any(n.op == "forge.linear_act" for n in g.nodes.values())
