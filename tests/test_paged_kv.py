"""Paged KV cache (ISSUE 6): page-pool / prefix-tree properties (no
double-free, refcounts match tree reachability, fork-then-free keeps
shared pages live), paged ≡ contiguous **bitwise** fidelity (decode,
batched prefill, GQA, window attention, mid-generation swap-in,
prefix-hit admission), and the Pallas paged-attention kernel against
its jnp reference in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # optional dep: skips when absent
from repro.configs import get_config
from repro.core.paging import (
    TRASH_PAGE,
    PagePool,
    PrefixTree,
    build_row_table,
    pages_for,
)
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import gather_pages, paged_sdpa_ref
from repro.launch.serve import BatchedServer, Request, SlotScheduler
from repro.models import get_model
from repro.models.attention import attention, attn_init, make_cache


def _tokens(n, seed=0, vocab=512):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, (n,)).astype(np.int32)


# --------------------------------------------------------------------------
# PagePool properties
# --------------------------------------------------------------------------


class TestPagePool:
    def test_alloc_fork_free_refcounts(self):
        pool = PagePool(8, 4)
        a = pool.alloc(3)
        assert pool.pages_in_use == 4  # 3 + pinned trash
        assert all(pool.refcount(p) == 1 for p in a)
        pool.fork(a)
        assert all(pool.refcount(p) == 2 for p in a)
        assert pool.free(a) == []  # refs drop to 1: nothing released
        assert sorted(pool.free(a)) == sorted(a)
        pool.check()
        assert pool.pages_in_use == 1  # only the trash page

    def test_double_free_raises(self):
        pool = PagePool(8, 4)
        a = pool.alloc(2)
        pool.free(a)
        with pytest.raises(ValueError, match="double free"):
            pool.free(a)
        pool.check()

    def test_trash_page_is_pinned(self):
        pool = PagePool(8, 4)
        assert TRASH_PAGE not in pool.alloc(pool.capacity)
        with pytest.raises(ValueError):
            pool.free([TRASH_PAGE])
        with pytest.raises(ValueError):
            pool.fork([TRASH_PAGE])

    def test_exhaustion_is_atomic(self):
        pool = PagePool(8, 4)
        pool.alloc(5)
        before = pool.pages_free
        with pytest.raises(MemoryError):
            pool.alloc(3)  # only 2 free
        assert pool.pages_free == before  # nothing leaked
        pool.check()

    @staticmethod
    def _run_ops(ops, num_pages=16):
        """Interpret an (op, idx) stream against the pool, checking the
        accounting invariant after every operation."""
        pool = PagePool(num_pages, 4)
        held = []  # page lists this "scheduler" owns
        for kind, idx in ops:
            if kind == 0:
                try:
                    held.append(pool.alloc(1 + idx % 3))
                except MemoryError:
                    pass
            elif kind == 1 and held:
                pages = held[idx % len(held)]
                pool.fork(pages)
                held.append(list(pages))
            elif kind == 2 and held:
                pool.free(held.pop(idx % len(held)))
            pool.check()
            assert pool.pages_in_use + pool.pages_free == pool.num_pages
        for pages in held:
            pool.free(pages)
        pool.check()
        assert pool.pages_in_use == 1  # everything returned except trash

    @pytest.mark.parametrize("seed", range(10))
    def test_random_ops_keep_invariant(self, seed):
        """No sequence of alloc/fork/free can double-free or leak."""
        rng = np.random.default_rng(seed)
        ops = [(int(rng.integers(0, 3)), int(rng.integers(0, 64)))
               for _ in range(60)]
        self._run_ops(ops)

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 63)),
                    max_size=80))
    @settings(max_examples=40, deadline=None)
    def test_random_ops_keep_invariant_hyp(self, ops):
        self._run_ops(ops)


# --------------------------------------------------------------------------
# PrefixTree properties
# --------------------------------------------------------------------------


class TestPrefixTree:
    def test_fork_then_free_leaves_shared_pages_live(self):
        """A slot retiring must not kill pages the tree (or another
        slot) still references."""
        pool = PagePool(32, 4)
        tree = PrefixTree(pool)
        toks = _tokens(16, seed=1)  # 4 full blocks
        pages = pool.alloc(4)
        tree.insert(toks, pages)
        pool.free(pages)  # first slot retires; tree refs keep them live
        m, n = tree.match(toks)
        assert n == 16 and len(m) == 4
        pool.fork(m)  # second slot shares the chain
        assert pool.free(m) == []  # ...and retires: tree still holds all
        m2, n2 = tree.match(toks)
        assert n2 == 16 and m2 == m
        pool.check()

    def test_refcounts_match_tree_reachability(self):
        """With no slots holding pages, every cached page's refcount is
        exactly the tree's one ref, and nothing else is in use."""
        pool = PagePool(64, 4)
        tree = PrefixTree(pool)
        rng = np.random.default_rng(2)
        base = _tokens(24, seed=3)  # 6 blocks
        for i in range(6):
            cut = 4 * int(rng.integers(1, 7))
            toks = np.concatenate([base[:cut], _tokens(8, seed=10 + i)])
            shared, skip = tree.match(toks, max_tokens=(len(toks) // 4) * 4)
            if shared:
                pool.fork(shared)
            n_pages = len(toks) // 4
            fresh = pool.alloc(n_pages - len(shared))
            tree.insert(toks[:n_pages * 4], list(shared) + fresh)
            pool.free(list(shared) + fresh)  # the slot retires at once
            pool.check()
        assert pool.pages_in_use == 1 + tree.cached_pages
        # the tree holds exactly one ref per cached page — reachability
        # equals refcount with no slot forks outstanding
        for nid in getattr(tree, "_nodes", {}):
            assert pool.refcount(tree._nodes[nid].page) == 1
        freed = tree.clear()
        pool.check()
        assert pool.pages_in_use == 1 and freed > 0

    def test_match_respects_token_cap(self):
        pool = PagePool(16, 4)
        tree = PrefixTree(pool)
        toks = _tokens(16, seed=4)
        tree.insert(toks, pool.alloc(4))
        m, n = tree.match(toks, max_tokens=8)
        assert n == 8 and len(m) == 2

    def test_reclaim_spares_forked_pages(self):
        """LRU reclaim frees tree-only chains; pages a live slot forked
        survive (refcount > 1)."""
        pool = PagePool(16, 4)
        tree = PrefixTree(pool)
        cold = _tokens(8, seed=5)
        hot = _tokens(8, seed=6)
        cold_pages = pool.alloc(2)
        tree.insert(cold, cold_pages)
        pool.free(cold_pages)  # slot retires: cold chain is tree-only
        hot_pages = pool.alloc(2)
        tree.insert(hot, hot_pages)  # this slot stays live (keeps refs)
        freed = tree.reclaim(4)
        assert freed == 2  # only the cold chain was evictable
        assert all(pool.refcount(p) >= 1 for p in hot_pages)
        m, n = tree.match(hot)
        assert n == 8  # hot chain survived
        pool.check()

    def test_build_row_table_pads_with_trash(self):
        row = build_row_table([3, 7], 4)
        assert row.dtype == np.int32
        assert list(row) == [3, 7, TRASH_PAGE, TRASH_PAGE]
        assert pages_for(17, 16) == 2 and pages_for(16, 16) == 1

    @given(st.lists(st.integers(0, 3), min_size=1, max_size=12),
           st.integers(1, 5))
    @settings(max_examples=30, deadline=None)
    def test_shared_prefix_reuse_hyp(self, symbols, reps):
        """Inserting the same token stream repeatedly never allocates
        new pages past the first insert, and refcounts stay consistent."""
        pool = PagePool(64, 2)
        tree = PrefixTree(pool)
        toks = np.asarray(symbols, np.int32)
        nfull = (len(toks) // 2) * 2
        if nfull == 0:
            return
        for _ in range(reps):
            shared, skip = tree.match(toks, max_tokens=nfull)
            if shared:
                pool.fork(shared)
            fresh = pool.alloc(nfull // 2 - len(shared))
            tree.insert(toks[:nfull], list(shared) + fresh)
            pool.free(list(shared) + fresh)
            pool.check()
        assert pool.pages_in_use == 1 + tree.cached_pages
        assert tree.cached_pages == nfull // 2


# --------------------------------------------------------------------------
# paged ≡ contiguous fidelity (bitwise)
# --------------------------------------------------------------------------


@pytest.fixture(scope="module", params=["forge-125m", "qwen2.5-14b"])
def fid_setup(request):
    """Dense MHA smoke + a GQA smoke (n_kv_heads < n_heads)."""
    cfg = get_config(request.param, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _identity_paged_cache(model, cfg, B, max_len, ps):
    """Paged cache whose tables map slot rows to disjoint page runs —
    the contiguous layout expressed through the indirection."""
    MP = max_len // ps
    cache = model.init_paged_cache(
        cfg, B, max_len, num_pages=1 + B * MP, page_size=ps
    )
    pt = np.zeros((B, MP), np.int32)
    for b in range(B):
        pt[b] = 1 + b * MP + np.arange(MP)
    cache["page_table"] = jnp.asarray(pt)
    return cache


class TestPagedDecodeFidelity:
    B, T, MAX_LEN, PS = 2, 9, 32, 8

    def test_decode_bitwise(self, fid_setup):
        """Token-at-a-time decode: the paged path must be bit-identical
        to the contiguous cache, dense and GQA alike."""
        cfg, model, params = fid_setup
        B, T, max_len = self.B, self.T, self.MAX_LEN
        cache = model.init_cache(cfg, B, max_len)
        pcache = _identity_paged_cache(model, cfg, B, max_len, self.PS)
        toks = np.stack([_tokens(T, seed=7, vocab=cfg.vocab),
                         _tokens(T, seed=8, vocab=cfg.vocab)])
        mask = jnp.ones((B,), bool)
        for t in range(T):
            tok = jnp.asarray(toks[:, t:t + 1])
            pos = jnp.full((B,), t, jnp.int32)
            la, cache = model.decode_step(params, cache, tok, pos, cfg,
                                          slot_mask=mask)
            lb, pcache = model.paged_decode_step(params, pcache, tok, pos,
                                                 cfg, slot_mask=mask)
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_prefill_then_decode_bitwise(self, fid_setup):
        """Whole-prompt paged prefill ≡ contiguous prefill, and the
        caches they leave behind decode identically."""
        cfg, model, params = fid_setup
        B, P, max_len = self.B, 12, self.MAX_LEN
        cache = model.init_cache(cfg, B, max_len)
        pcache = _identity_paged_cache(model, cfg, B, max_len, self.PS)
        toks = jnp.asarray(np.stack([
            _tokens(P, seed=9, vocab=cfg.vocab),
            _tokens(P, seed=10, vocab=cfg.vocab),
        ]))
        mask = jnp.ones((B,), bool)
        la, cache = model.prefill_step(params, cache, toks, 0, cfg,
                                       slot_mask=mask)
        lb, pcache = model.paged_prefill_step(params, pcache, toks, 0, cfg,
                                             slot_mask=mask)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        tok = jnp.argmax(la[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        for t in range(3):
            pos = jnp.full((B,), P + t, jnp.int32)
            la, cache = model.decode_step(params, cache, tok, pos, cfg,
                                          slot_mask=mask)
            lb, pcache = model.paged_decode_step(params, pcache, tok, pos,
                                                 cfg, slot_mask=mask)
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
            tok = jnp.argmax(la[:, -1, :], axis=-1).astype(jnp.int32)[:, None]

    def test_masked_rows_leave_pages_untouched(self, fid_setup):
        """slot_mask=False rows write nothing: their writes land on the
        trash page, so every real page survives bitwise."""
        cfg, model, params = fid_setup
        B, max_len = self.B, self.MAX_LEN
        pcache = _identity_paged_cache(model, cfg, B, max_len, self.PS)
        mask = jnp.asarray([True, False])
        tok = jnp.asarray([[3], [5]], jnp.int32)
        pos = jnp.asarray([0, 0], jnp.int32)
        _, out = model.paged_decode_step(params, pcache, tok, pos, cfg,
                                         slot_mask=mask)
        MP = max_len // self.PS
        row1_pages = np.asarray(pcache["page_table"])[1]
        for name in ("k_pages", "v_pages"):
            new = np.asarray(out[name])
            assert np.all(new[:, row1_pages] == 0.0), \
                "masked row wrote into its own pages"

    def test_window_attention_bitwise(self):
        """Sliding-window decode through the paged cache matches the
        contiguous rotating mask path bitwise (attention-layer level)."""
        H, KVH, D, max_len, ps, window = 4, 2, 8, 32, 8, 8
        B, d_model = 2, 32
        key = jax.random.PRNGKey(1)
        p = attn_init(key, d_model, H, KVH, D, dtype=jnp.float32)
        cache = make_cache(B, KVH, max_len, D, dtype=jnp.float32)
        MP = max_len // ps
        pt = np.zeros((B, MP), np.int32)
        for b in range(B):
            pt[b] = 1 + b * MP + np.arange(MP)
        pt_dev = jnp.asarray(pt)
        store = {
            "k_pages": jnp.zeros((1 + B * MP, ps, KVH, D), jnp.float32),
            "v_pages": jnp.zeros((1 + B * MP, ps, KVH, D), jnp.float32),
        }
        rng = np.random.default_rng(11)
        mask = jnp.ones((B,), bool)
        for t in range(2 * window):  # run PAST the window edge
            x = jnp.asarray(rng.standard_normal((B, 1, d_model)),
                            jnp.float32)
            pos = jnp.full((B,), t, jnp.int32)
            oa, cache = attention(x, p, n_heads=H, n_kv_heads=KVH,
                                  window=window, cache=cache, cache_pos=pos)
            # the returned store has no table — the table rides separately
            # (steps.py passes it per dispatch), so re-attach each step
            ob, store = attention(x, p, n_heads=H, n_kv_heads=KVH,
                                  window=window,
                                  cache={**store, "page_table": pt_dev},
                                  cache_pos=pos, write_mask=mask)
            np.testing.assert_array_equal(np.asarray(oa), np.asarray(ob))


class TestPagedSchedulerFidelity:
    """End-to-end: the paged SlotScheduler emits bitwise the contiguous
    scheduler's tokens — through rung resizes, mid-generation swap-ins,
    and prefix-tree admission hits."""

    MAX_LEN, PS = 32, 8

    def _requests(self, vocab):
        shared = _tokens(16, seed=20, vocab=vocab)  # 2 shared pages
        reqs = []
        for i in range(8):
            if i % 3 == 0:  # shared-prefix group → prefix-tree hits
                p = np.concatenate([shared,
                                    _tokens(4, seed=30 + i, vocab=vocab)])
            else:
                p = _tokens(3 + 2 * (i % 5), seed=40 + i, vocab=vocab)
            reqs.append(Request(rid=i, prompt=p,
                                max_new=2 + (3 * i) % 5, arrival=i // 3))
        return reqs

    def _run(self, cfg, params, paged, **kw):
        srv = BatchedServer(cfg, params, max_len=self.MAX_LEN, mode="forge",
                            backend="interpret",
                            seq_bucket_policy="ladder:8,16,32",
                            paged=paged, kv_page_size=self.PS, **kw)
        sched = SlotScheduler(srv, max_slots=4)
        sched.warmup(prompt_lens=[4, 8, 16, 24])
        res = sched.run(self._requests(cfg.vocab))
        if paged:
            srv.page_pool.check()
            # every slot freed its pages: only the trash page and the
            # prefix tree's cached chains remain referenced
            assert srv.page_pool.pages_in_use == \
                1 + srv.prefix_tree.cached_pages
        return res

    def test_swap_in_and_prefix_hits_bitwise(self, fid_setup):
        cfg, _, params = fid_setup
        ra = self._run(cfg, params, paged=False)
        rb = self._run(cfg, params, paged=True)
        assert rb["swaps"] >= 1, "workload must exercise swap-in"
        assert rb["prefix_hits"] >= 1, "workload must hit the prefix tree"
        assert rb["tokens_reused"] >= 16
        assert set(ra["results"]) == set(rb["results"])
        for rid in ra["results"]:
            np.testing.assert_array_equal(
                ra["results"][rid]["tokens"], rb["results"][rid]["tokens"],
                err_msg=f"rid {rid} diverged between paged and contiguous",
            )

    def test_pool_exhaustion_defers_and_completes(self, fid_setup):
        """A pool too small for all concurrent admissions bounces the
        overflow back to the queue; every request still completes with
        the same tokens."""
        cfg, _, params = fid_setup
        ra = self._run(cfg, params, paged=False)
        # capacity 5: the first admission wave wants 7 pages, so at
        # least one request bounces and re-admits after a retirement
        rb = self._run(cfg, params, paged=True, kv_pages=6)
        assert rb["deferrals"] >= 1, "pool must have been exhausted"
        assert set(ra["results"]) == set(rb["results"])
        for rid in ra["results"]:
            np.testing.assert_array_equal(
                ra["results"][rid]["tokens"], rb["results"][rid]["tokens"])


# --------------------------------------------------------------------------
# Pallas paged-attention kernel vs jnp reference (interpret mode)
# --------------------------------------------------------------------------


class TestPagedAttentionKernel:
    def _case(self, seed, B, H, KVH, D, ps, MP, window, dtype):
        rng = np.random.default_rng(seed)
        NP = 1 + B * MP
        q = jnp.asarray(rng.standard_normal((B, H, D)), dtype)
        k = jnp.asarray(rng.standard_normal((NP, ps, KVH, D)), dtype)
        v = jnp.asarray(rng.standard_normal((NP, ps, KVH, D)), dtype)
        pt = np.zeros((B, MP), np.int32)
        for b in range(B):
            pt[b] = 1 + b * MP + rng.permutation(MP)  # non-contiguous!
        pos = rng.integers(0, MP * ps, (B,)).astype(np.int32)
        pt, pos = jnp.asarray(pt), jnp.asarray(pos)
        out = paged_attention(q, k, v, pt, pos, window=window,
                              interpret=True)
        ref = paged_sdpa_ref(q, k, v, pt, pos, window=window)
        assert out.dtype == q.dtype
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol,
        )

    @pytest.mark.parametrize(
        "seed,B,H,KVH,D,ps,MP,window",
        [
            (0, 2, 4, 4, 8, 8, 4, None),   # MHA
            (1, 2, 4, 2, 8, 8, 4, None),   # GQA
            (2, 3, 6, 2, 16, 4, 6, None),  # deeper GQA, small pages
            (3, 2, 4, 2, 8, 8, 4, 8),      # sliding window
            (4, 1, 8, 8, 32, 16, 2, 16),   # wide head, window
        ],
    )
    def test_kernel_matches_reference(self, seed, B, H, KVH, D, ps, MP,
                                      window):
        self._case(seed, B, H, KVH, D, ps, MP, window, jnp.float32)

    def test_kernel_bf16(self):
        self._case(5, 2, 4, 2, 8, 8, 4, None, jnp.bfloat16)

    def test_gather_pages_reconstructs_contiguous_layout(self):
        rng = np.random.default_rng(6)
        B, KVH, D, ps, MP = 2, 2, 4, 4, 3
        NP = 1 + B * MP
        pages = jnp.asarray(rng.standard_normal((NP, ps, KVH, D)),
                            jnp.float32)
        pt = np.zeros((B, MP), np.int32)
        for b in range(B):
            pt[b] = 1 + b * MP + np.arange(MP)
        view = np.asarray(gather_pages(pages, jnp.asarray(pt)))
        assert view.shape == (B, KVH, MP * ps, D)
        flat = np.asarray(pages)
        for b in range(B):
            expect = flat[pt[b]].reshape(MP * ps, KVH, D)
            np.testing.assert_array_equal(
                view[b], expect.transpose(1, 0, 2)
            )

    def test_fully_masked_row_yields_zeros_not_nan(self):
        """pos = -1 keeps every key masked; the kernel's l==0 guard must
        return zeros instead of 0/0 NaNs."""
        B, H, KVH, D, ps, MP = 1, 2, 2, 8, 4, 2
        q = jnp.ones((B, H, D), jnp.float32)
        k = jnp.ones((1 + MP, ps, KVH, D), jnp.float32)
        v = jnp.ones((1 + MP, ps, KVH, D), jnp.float32)
        pt = jnp.asarray([[1, 2]], jnp.int32)
        pos = jnp.asarray([-1], jnp.int32)
        out = paged_attention(q, k, v, pt, pos, interpret=True)
        assert np.all(np.isfinite(np.asarray(out)))
