"""Zero-copy Phase-4 execution (ISSUE 3): donation safety, precompiled
dispatch plans over the pooled flat buffer file, per-bucket buffer
pooling, and per-constant fingerprint memoization.

The donation property tests are seed-parametrized random RGIR programs
(same convention as test_scheduler_props): a donated live-in must never
be read after its segment, never be caller-owned (program input or
constant), and must have a live-out of identical aval for XLA to alias
its buffer onto — and donated-path outputs must match the unscheduled,
unallocated ``reference`` oracle.
"""
import gc
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BufferPool,
    CompileCache,
    ForgeCompiler,
    PipelineConfig,
)
from repro.core.backends import SegmentExecutor
from repro.core.bufalloc import segment_donations
from repro.core.capture import trace_to_graph
from repro.core.executor import analyze_program
from repro.core.lowering import lower_to_rgir
from repro.core.passes import run_forge_passes
from repro.core.shapekey import BucketStats


def random_dag_program(seed: int, n_ops: int = 12):
    """Lower a random primitive DAG mixing host and accel ops.

    Matmul-heavy relative to test_scheduler_props' generator so device
    transitions (and therefore dying live-ins crossing segment
    boundaries) are frequent — the donation analysis' target shape.
    """
    rng = np.random.default_rng(seed)

    def f(x):
        vals = [x]
        for _ in range(n_ops):
            a = vals[int(rng.integers(0, len(vals)))]
            b = vals[int(rng.integers(0, len(vals)))]
            op = int(rng.integers(0, 4))
            if op == 0:
                vals.append(a + b)  # host
            elif op == 1:
                vals.append(a * 0.5 + jnp.tanh(b))  # host
            else:
                vals.append(a @ b)  # accel (dot_general)
        return vals[-1]

    return lower_to_rgir(trace_to_graph(f, np.ones((4, 4), np.float32)).graph)


SEEDS = list(range(20))


def _segment_executor(prog, **kw):
    return SegmentExecutor(analyze_program(prog), warmup=False, **kw)


def _block_prog(block_fn, block_args):
    g = trace_to_graph(block_fn, *block_args).graph
    run_forge_passes(g)
    return lower_to_rgir(g)


class TestDonationSafety:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_donated_regs_die_inside_their_segment(self, seed):
        """A donated live-in is never read by any later instruction."""
        ex = _segment_executor(random_dag_program(seed))
        for seg in ex.segments:
            for pos in seg.donate_argnums:
                r = seg.live_in[pos]
                s, e = ex.live.intervals[r]
                assert s >= 0, "caller-owned register donated"
                assert seg.start <= e < seg.stop, "donated reg outlives segment"
                assert r in seg.free_after
                assert r not in ex.live.pinned
                for op in ex.prog.ops[seg.stop:]:
                    assert r not in op.input_regs, (
                        f"r{r} donated in seg{seg.index} but read later"
                    )

    @pytest.mark.parametrize("seed", SEEDS)
    def test_inputs_and_constants_never_donated(self, seed):
        ex = _segment_executor(random_dag_program(seed))
        caller_owned = set(ex.prog.input_regs) | set(ex.prog.constants)
        for seg in ex.segments:
            donated = {seg.live_in[p] for p in seg.donate_argnums}
            assert not (donated & caller_owned)

    @pytest.mark.parametrize("seed", SEEDS)
    def test_donated_avals_match_a_live_out(self, seed):
        """Every donated buffer must be usable: one live-out of identical
        shape/dtype per donated arg (multiset-matched, no double use)."""
        ex = _segment_executor(random_dag_program(seed))
        ra = ex.prog.reg_avals
        for seg in ex.segments:
            outs = [
                (tuple(ra[r].shape), str(ra[r].dtype)) for r in seg.live_out
            ]
            for pos in seg.donate_argnums:
                r = seg.live_in[pos]
                key = (tuple(ra[r].shape), str(ra[r].dtype))
                assert key in outs
                outs.remove(key)

    def test_block_graph_donates(self, block_fn, block_args):
        """The fused transformer block must exercise the donated path."""
        ex = _segment_executor(_block_prog(block_fn, block_args))
        assert ex.stats.n_donating_segments >= 1
        assert ex.stats.n_donated_args >= 1

    def test_donation_analysis_unit(self):
        """Direct check of the candidate conditions on a crafted segment."""
        from repro.core.liveness import LivenessInfo
        from repro.core._jax_internal import ShapedArray

        aval = ShapedArray((4, 4), np.dtype(np.float32))
        live = LivenessInfo(
            intervals={0: (-1, 5), 1: (2, 5), 2: (1, 9), 3: (6, 11)},
            dead_after={},
            pinned=set(),
        )
        avals = {r: aval for r in (0, 1, 2, 3)}
        # segment [4, 8): r0 (input) and r1 die inside; r2 lives past it
        donate = segment_donations(
            live, avals, live_in=(0, 1, 2), live_out=(3,),
            free_after=(0, 1),
        )
        assert donate == (1,)  # r1 only: r0 is caller-owned, r2 survives


class TestDonationFidelity:
    @pytest.mark.parametrize("seed", SEEDS[:10])
    def test_matches_reference_oracle(self, seed):
        from repro.core.backends import get_backend

        prog = random_dag_program(seed)
        x = np.random.default_rng(seed).standard_normal((4, 4)).astype(
            np.float32
        ) * 0.1
        ref_out = get_backend("reference").build(prog).execute(x)
        seg_ex = SegmentExecutor(analyze_program(prog))
        for _ in range(2):  # repeat: pooled file reuse must stay correct
            out = seg_ex.execute(x)
            diff = max(
                float(np.max(np.abs(np.asarray(a, np.float32)
                                    - np.asarray(b, np.float32))))
                for a, b in zip(ref_out, out)
            )
            assert diff <= 1e-5

    def test_donated_vs_nondonated_identical(self, block_fn, block_args):
        prog = _block_prog(block_fn, block_args)
        a = SegmentExecutor(analyze_program(prog), donate=True)
        b = SegmentExecutor(analyze_program(prog), donate=False)
        flat = [np.asarray(x) for x in block_args]
        out_a = a.execute(*flat)
        out_b = b.execute(*flat)
        for va, vb in zip(out_a, out_b):
            np.testing.assert_allclose(
                np.asarray(va, np.float32), np.asarray(vb, np.float32),
                atol=1e-5, rtol=0,
            )


class TestDispatchPlans:
    def test_zero_buffer_file_allocs_steady_state(self, block_fn, block_args):
        """After the first call every call reuses the pooled buffer file."""
        mod = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        for _ in range(4):
            mod(*block_args)
        assert mod.stats.file_pool_misses == 1
        assert mod.stats.file_pool_hits == 3

    def test_interpret_backend_pools_too(self, block_fn, block_args):
        mod = ForgeCompiler(
            PipelineConfig(backend="interpret"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        for _ in range(3):
            mod(*block_args)
        assert mod.stats.file_pool_misses == 1
        assert mod.stats.file_pool_hits == 2

    def test_pooled_replay_is_deterministic(self, block_fn, block_args):
        mod = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        first = np.asarray(mod(*block_args), np.float32)
        for _ in range(3):
            np.testing.assert_array_equal(
                first, np.asarray(mod(*block_args), np.float32)
            )

    def test_constants_survive_pooled_reuse(self):
        """Regression: a constant read after another reg's free must still
        be present on the second (pooled-file) call."""

        def f(x):
            c = jnp.asarray(np.arange(16, dtype=np.float32).reshape(4, 4))
            y = x @ c  # c read on the accel side
            return y + c  # ... and on the host side after frees

        x = np.ones((4, 4), np.float32)
        mod = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=CompileCache()
        ).compile(f, x)
        a = np.asarray(mod(x))
        b = np.asarray(mod(x))
        np.testing.assert_array_equal(a, b)

    def test_concurrent_execute_thread_safe(self, block_fn, block_args):
        """Overlapping calls must not share one buffer file."""
        mod = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        expect = np.asarray(mod(*block_args), np.float32)
        errs = []

        def worker():
            try:
                for _ in range(3):
                    got = np.asarray(mod(*block_args), np.float32)
                    np.testing.assert_array_equal(got, expect)
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=worker) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs

    def test_static_peak_matches_dynamic_semantics(self, block_fn, block_args):
        """The precomputed peak is per-call-stable and bounded by the file."""
        mod = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        mod(*block_args)
        p1 = mod.stats.last_peak_live_buffers
        mod(*block_args)
        assert mod.stats.last_peak_live_buffers == p1
        assert 0 < p1 <= mod.stats.n_buffers

    def test_fresh_snapshot_zeroes_pool_counters(self, block_fn, block_args):
        mod = ForgeCompiler(
            PipelineConfig(backend="segment_jit"), cache=CompileCache()
        ).compile(block_fn, *block_args)
        mod(*block_args)
        snap = mod.stats.fresh_snapshot()
        assert snap.file_pool_hits == snap.file_pool_misses == 0
        assert snap.total_donated_args == 0
        assert snap.n_donated_args == mod.stats.n_donated_args


class TestWarmupDedup:
    def test_warmup_zeros_shared_by_aval(self, block_fn, block_args,
                                         monkeypatch):
        """AOT warmup builds at most one zero array per distinct aval."""
        import repro.core.backends.segment_jit as sj

        calls = []
        real_zeros = np.zeros

        def counting_zeros(*a, **kw):
            calls.append(a)
            return real_zeros(*a, **kw)

        monkeypatch.setattr(sj.np, "zeros", counting_zeros)
        prog = _block_prog(block_fn, block_args)
        ex = SegmentExecutor(analyze_program(prog), warmup=True)
        # patching np.zeros is global: keep only the warmup's own calls
        # (``np.zeros(shape_tuple, dtype)`` — two positional args)
        calls = [
            a for a in calls
            if len(a) == 2 and isinstance(a[0], tuple)
            and isinstance(a[1], np.dtype)
        ]
        distinct = {
            (tuple(prog.reg_avals[r].shape), str(prog.reg_avals[r].dtype))
            for seg in ex.segments if seg.compiled
            for r in seg.live_in
        }
        total_live_ins = sum(
            len(seg.live_in) for seg in ex.segments if seg.compiled
        )
        assert len(calls) <= len(distinct)
        assert total_live_ins > len(distinct)  # dedup actually saved builds


class TestBufferPool:
    def test_hit_miss_and_bytes(self):
        stats = BucketStats()
        pool = BufferPool(stats)
        build = lambda: {"k": np.zeros((8, 8), np.float32)}  # noqa: E731
        t1 = pool.acquire("B8", build)
        assert stats.pool_misses == 1 and stats.pool_hits == 0
        pool.release("B8", t1)
        t2 = pool.acquire("B8", build)
        assert t2 is t1  # reused, not rebuilt
        assert stats.pool_hits == 1
        assert stats.pool_bytes_reused == 8 * 8 * 4
        assert stats.pool_hit_rate == 0.5

    def test_reset_applied_on_hit(self):
        pool = BufferPool(BucketStats())
        tree = {"k": np.full((4,), 7.0, np.float32)}
        pool.release("x", tree)
        got = pool.acquire(
            "x", build=lambda: pytest.fail("should not rebuild"),
            reset=lambda t: {"k": np.zeros_like(t["k"])},
        )
        np.testing.assert_array_equal(got["k"], 0.0)

    def test_failing_reset_falls_back_to_build(self):
        stats = BucketStats()
        pool = BufferPool(stats)
        pool.release("x", {"k": np.zeros(4)})

        def bad_reset(t):
            raise RuntimeError("aliased buffers")

        fresh = {"k": np.ones(4)}
        got = pool.acquire("x", build=lambda: fresh, reset=bad_reset)
        assert got is fresh
        assert stats.pool_misses == 1 and stats.pool_hits == 0

    def test_release_capped(self):
        pool = BufferPool(BucketStats(), max_per_key=2)
        for _ in range(5):
            pool.release("k", {"a": np.zeros(1)})
        assert pool.pooled("k") == 2

    def test_keys_are_independent(self):
        pool = BufferPool(BucketStats())
        pool.release(2, "two")
        pool.release(4, "four")
        assert pool.acquire(4, build=lambda: "fresh") == "four"
        assert pool.acquire(2, build=lambda: "fresh") == "two"
        assert pool.acquire(2, build=lambda: "fresh") == "fresh"


class TestFingerprintMemo:
    def test_large_constant_hashed_once(self):
        from repro.core import cache as C

        big = np.random.default_rng(0).standard_normal((64, 64)).astype(
            np.float32
        )

        def digest_of(v):
            import hashlib

            h = hashlib.sha256()
            C._hash_value(h, v)
            return h.hexdigest()

        h0 = C.fp_memo_stats.hits
        d1 = digest_of(big)
        d2 = digest_of(big)
        assert d1 == d2
        assert C.fp_memo_stats.hits == h0 + 1  # second hash was a memo hit

    def test_different_content_different_digest(self):
        import hashlib

        from repro.core import cache as C

        a = np.zeros((64, 64), np.float32)
        b = np.zeros((64, 64), np.float32)
        b[0, 0] = 1.0
        ha, hb = hashlib.sha256(), hashlib.sha256()
        C._hash_value(ha, a)
        C._hash_value(hb, b)
        assert ha.hexdigest() != hb.hexdigest()

    def test_memo_entry_dropped_on_collection(self):
        import hashlib

        from repro.core import cache as C

        v = np.ones((64, 64), np.float32)
        C._hash_value(hashlib.sha256(), v)
        key = id(v)
        assert key in C._FP_MEMO
        del v
        gc.collect()
        assert key not in C._FP_MEMO

    def test_program_fingerprint_stable_under_memo(self, block_fn,
                                                   block_args):
        from repro.core import fingerprint_program

        prog = _block_prog(block_fn, block_args)
        assert fingerprint_program(prog) == fingerprint_program(prog)
