"""End-to-end example: train a ~100M-param LM for a few hundred steps.

This drives the REAL stack — Forge-compiled blocks, AdamW, deterministic
data pipeline, async checkpointing, fault-tolerant supervisor — on a
GPT-2-class config scaled to fit the CPU container's patience
(--full uses the true 125M layout).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="true 125M config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/forge_train_lm")
    args = ap.parse_args()

    argv = [
        "--arch", "forge-125m",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
    ]
    if not args.full:
        argv.append("--smoke")
    return train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
