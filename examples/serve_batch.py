"""Batched serving example: prefill + decode with the compiled executor,
comparing the two execution modes the paper contrasts:

* ``jit``       — one fused XLA program (NNFactory compile-then-run)
* ``interpret`` — per-instruction flat dispatch (the per-op NPU world)

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch xlstm-350m]
"""
import argparse

import numpy as np
import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.serve import BatchedServer
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b",
                    choices=ARCH_IDS + ["forge-125m"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced config on CPU
    if cfg.family == "encdec":
        raise SystemExit("enc-dec serving: see repro/models/encdec.py decode")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, 16)).astype(np.int32)

    for mode in ("jit", "interpret"):
        server = BatchedServer(cfg, params, max_len=64, mode=mode)
        res = server.generate(prompts, args.gen)
        print(f"[{mode:9s}] decode mean={res['decode_ms_mean']:7.2f} ms  "
              f"p99={res['decode_ms_p99']:7.2f} ms  "
              f"{res['tok_per_s']:.0f} tok/s")
    print("note: jit amortizes dispatch; interpret mode exposes the "
          "per-instruction overhead the paper's scheduler minimizes.")


if __name__ == "__main__":
    main()
