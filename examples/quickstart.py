"""Quickstart: compile a transformer block with Forge-UGC and inspect
every phase — the paper's transparency pitch in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForgeCompiler, PipelineConfig
from repro.core.metrics import fidelity, fusion_gain_ratio


def gqa_block(x, wq, wk, wv, wo, w_gate, w_up, w_down):
    """An unfused GQA transformer block (what the compiler sees)."""
    B, S, E = x.shape
    H, KVH = 8, 2
    D = E // H
    q = (x @ wq).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(B, S, KVH, D).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(B, S, KVH, D).transpose(0, 2, 1, 3)
    g = H // KVH
    k = jnp.broadcast_to(k[:, :, None], (B, KVH, g, S, D)).reshape(B, H, S, D)
    v = jnp.broadcast_to(v[:, :, None], (B, KVH, g, S, D)).reshape(B, H, S, D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (1.0 / np.sqrt(D))
    row = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (S, S), 1)
    s = jnp.where(row >= col, s, jnp.finfo(s.dtype).min)
    o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
    x = x + o.transpose(0, 2, 1, 3).reshape(B, S, E) @ wo
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)  # SwiGLU, unfused
    return x + h @ w_down


def main():
    rng = np.random.default_rng(0)
    B, S, E, F = 2, 64, 64, 128
    args = [rng.standard_normal(s).astype(np.float32) * 0.1 for s in
            [(B, S, E), (E, E), (E, E // 4), (E, E // 4), (E, E),
             (E, F), (E, F), (F, E)]]

    # four phases: capture -> 6 passes -> RGIR -> scheduled executor
    mod = ForgeCompiler(PipelineConfig()).compile(gqa_block, *args)

    print("=== CompilationResult (paper Limitation 2: full transparency) ===")
    print(mod.result.summary())
    print("\n=== per-pass profile (paper Table 10) ===")
    for row in mod.result.pass_table():
        print(f"  {row['pass']:20s} {row['time_ms']:8.2f} ms "
              f"delta_nodes={row['delta_nodes']:+4d}  {row['detail']}")

    print("\n=== fused graph ===")
    for node in mod.graph.nodes.values():
        if node.op.startswith("forge."):
            print(f"  {node.op}  params={ {k: v for k, v in node.params.items() if k != 'impl'} }")

    # numerical fidelity (paper Table 6 protocol)
    pre = gqa_block(*args)
    post = mod(*args)
    rep = fidelity(pre, post)
    print(f"\nfidelity: max-abs={rep.max_abs_diff:.2e} KL={rep.kl_divergence:.2e}")

    fgr = fusion_gain_ratio(gqa_block, *args)
    print(f"FGR (Eq. 22): {fgr['fgr']:.1f}")

    # the compiled executor also runs as ONE jitted XLA program
    y = mod.jit()(*args)
    print(f"jit output shape: {np.asarray(y).shape} — OK")


if __name__ == "__main__":
    main()
