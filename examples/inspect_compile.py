"""Compile every assigned architecture's block through Forge-UGC and
print the per-arch fusion report — the paper's Table 5 (node reduction)
live on the real model zoo.

Run:  PYTHONPATH=src python examples/inspect_compile.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import ForgeCompiler, PipelineConfig
from repro.models import get_model, layers as L
from repro.models import transformer as T


def main():
    print(f"{'arch':30s} {'nodes':>12s} {'red%':>6s} {'fused':>6s} "
          f"{'attn':>5s} {'rho_buf':>8s} {'delta':>10s}")
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True).with_(fuse="none")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg)
        tokens = jnp.zeros((2, 16), jnp.int32)

        if cfg.family in ("dense", "moe", "vlm"):
            one = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
            x = jnp.zeros((2, 16, cfg.d_model), jnp.dtype(cfg.dtype))
            cos, sin = L.rope_tables(jnp.arange(16), cfg.head_dim_,
                                     cfg.rope_theta)
            fn = lambda p, x, c, s: T.block_apply(p, x, c, s, cfg)  # noqa: E731
            args = (one, x, cos, sin)
        else:
            # whole-model capture for the heterogeneous families
            if cfg.family == "encdec":
                frames = jnp.zeros((2, 16, cfg.d_model), jnp.dtype(cfg.dtype))
                fn = lambda p, f, t: model.apply(p, f, t, cfg)  # noqa: E731
                args = (params, frames, tokens)
            else:
                fn = lambda p, t: model.apply(p, t, cfg)  # noqa: E731
                args = (params, tokens)

        mod = ForgeCompiler(PipelineConfig()).compile(fn, *args)
        r = mod.result
        s = r.executor_stats
        print(f"{arch:30s} {r.nodes_before:5d}->{r.nodes_after:5d} "
              f"{100*r.node_reduction:5.1f}% {r.fused_ops:6d} "
              f"{r.attention_fused:5d} {s.rho_buf:7.1%} "
              f"{s.delta_before:4d}->{s.delta_after:<4d}")
    print("\n(xlstm shows attention_fused=0: documented inapplicability — "
          "no softmax-attention subgraph exists in that family)")


if __name__ == "__main__":
    main()
